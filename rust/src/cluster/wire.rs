//! Wire codecs for the cluster verbs — the payload half of the
//! length-prefixed binary protocol frames that carry routed batches,
//! boundary-exchange rounds, and shard manifests between a router and a
//! remote `pico serve`.
//!
//! Everything is little-endian with explicit `u64` counts, decoded with
//! the same paranoia as [`crate::shard::snapshot`]: counts are checked
//! against the actual byte budget *before* any allocation, trailing
//! garbage is rejected, and the shard manifest re-validates the embedded
//! index snapshot in full (CSR structure + coreness invariants), so a
//! corrupt or hostile payload is refused without touching server state.
//!
//! # Shard manifest
//!
//! The manifest is the unit of shard shipping and replica catch-up: the
//! shard's subgraph snapshot ([`crate::shard::snapshot`] bytes — graph,
//! local coreness, shard epoch) plus everything the snapshot alone lacks
//! to serve as a cluster shard — the local→global id table, the owned
//! set, the committed refined (exact global) coreness, and the cluster
//! epoch it was committed at. Both magics here
//! ([`crate::net::codec::MANIFEST_MAGIC`],
//! [`crate::net::codec::DELTA_MAGIC`]) are defined in
//! [`crate::net::codec`] — the single home of every wire magic — and
//! decoding reads through its shared bounds-checked
//! [`crate::net::codec::Cursor`]:
//!
//! ```text
//! magic         MANIFEST_MAGIC                           8 bytes
//! shard_id      u32        num_shards  u32
//! cluster_epoch u64
//! counts        u64 globals_len, u64 owned_len, u64 refined_len, u64 snapshot_len
//! globals       globals_len × u32     (local id -> global id)
//! owned         owned_len × u32       (owned local ids)
//! refined       refined_len × u32     (0 or globals_len entries)
//! snapshot      snapshot_len bytes    (a SNAPSHOT_MAGIC payload)
//! ```

use super::journal::EpochDelta;
use crate::core::maintenance::EdgeEdit;
use crate::graph::VertexId;
use crate::net::codec::{Cursor, DELTA_MAGIC, HANDOFF_MAGIC, MANIFEST_MAGIC};
use crate::shard::backend::{RefineInit, RoutedBatch};
use crate::shard::snapshot::{self, IndexSnapshot};
use anyhow::{bail, Context, Result};

fn put_u32s(out: &mut Vec<u8>, vals: &[u32]) {
    out.extend_from_slice(&(vals.len() as u64).to_le_bytes());
    for &v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn take_u32s(c: &mut Cursor, what: &str) -> Result<Vec<u32>> {
    let n = c.count(4, what)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(c.u32()?);
    }
    Ok(out)
}

fn put_pairs(out: &mut Vec<u8>, pairs: &[(VertexId, u32)]) {
    out.extend_from_slice(&(pairs.len() as u64).to_le_bytes());
    for &(v, e) in pairs {
        out.extend_from_slice(&v.to_le_bytes());
        out.extend_from_slice(&e.to_le_bytes());
    }
}

fn take_pairs(c: &mut Cursor, what: &str) -> Result<Vec<(VertexId, u32)>> {
    let n = c.count(8, what)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let v = c.u32()?;
        let e = c.u32()?;
        out.push((v, e));
    }
    Ok(out)
}

/// `(vertex, estimate)` pairs — exchange-round updates and replies.
pub fn encode_pairs(pairs: &[(VertexId, u32)]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + pairs.len() * 8);
    put_pairs(&mut out, pairs);
    out
}

pub fn decode_pairs(bytes: &[u8]) -> Result<Vec<(VertexId, u32)>> {
    let mut c = Cursor::new(bytes);
    let pairs = take_pairs(&mut c, "pairs")?;
    c.done("pairs")?;
    Ok(pairs)
}

/// Bare vertex lists — `SHARDMEMBERS` replies.
pub fn encode_u32s(vals: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + vals.len() * 4);
    put_u32s(&mut out, vals);
    out
}

pub fn decode_u32s(bytes: &[u8]) -> Result<Vec<u32>> {
    let mut c = Cursor::new(bytes);
    let vals = take_u32s(&mut c, "u32 list")?;
    c.done("u32 list")?;
    Ok(vals)
}

/// A routed batch (`SHARDAPPLY` request payload). Edit flags: bit 0 =
/// insert (else delete), bit 1 = primary copy.
pub fn encode_batch(batch: &RoutedBatch) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + batch.new_owned.len() * 4 + batch.edits.len() * 9);
    put_u32s(&mut out, &batch.new_owned);
    out.extend_from_slice(&(batch.edits.len() as u64).to_le_bytes());
    for &(e, primary) in &batch.edits {
        let (u, v) = match e {
            EdgeEdit::Insert(u, v) => (u, v),
            EdgeEdit::Delete(u, v) => (u, v),
        };
        let flags = (e.is_insert() as u8) | ((primary as u8) << 1);
        out.push(flags);
        out.extend_from_slice(&u.to_le_bytes());
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

pub fn decode_batch(bytes: &[u8]) -> Result<RoutedBatch> {
    let mut c = Cursor::new(bytes);
    let new_owned = take_u32s(&mut c, "new-owned")?;
    let n = c.count(9, "edit")?;
    let mut edits = Vec::with_capacity(n);
    for _ in 0..n {
        let flags = c.u8()?;
        if flags > 3 {
            bail!("bad edit flags {flags:#x}");
        }
        let u = c.u32()?;
        let v = c.u32()?;
        if u == v {
            bail!("self-loop edit ({u},{u})");
        }
        let e = if flags & 1 != 0 {
            EdgeEdit::Insert(u, v)
        } else {
            EdgeEdit::Delete(u, v)
        };
        edits.push((e, flags & 2 != 0));
    }
    c.done("routed batch")?;
    Ok(RoutedBatch { new_owned, edits })
}

/// A refine-start reply (`SHARDREFINE START` payload).
pub fn encode_refine_init(init: &RefineInit) -> Vec<u8> {
    let mut out =
        Vec::with_capacity(32 + init.owned_est.len() * 8 + init.ghosts.len() * 4);
    put_pairs(&mut out, &init.owned_est);
    put_u32s(&mut out, &init.ghosts);
    out.extend_from_slice(&init.arcs.to_le_bytes());
    out.extend_from_slice(&init.boundary_arcs.to_le_bytes());
    out
}

pub fn decode_refine_init(bytes: &[u8]) -> Result<RefineInit> {
    let mut c = Cursor::new(bytes);
    let owned_est = take_pairs(&mut c, "owned estimates")?;
    let ghosts = take_u32s(&mut c, "ghosts")?;
    let arcs = c.u64()?;
    let boundary_arcs = c.u64()?;
    c.done("refine init")?;
    if boundary_arcs > arcs {
        bail!("boundary arcs {boundary_arcs} exceed total arcs {arcs}");
    }
    Ok(RefineInit {
        owned_est,
        ghosts,
        arcs,
        boundary_arcs,
    })
}

/// Serialise a contiguous delta chain (`SHARDDELTA <from> <to>`
/// payload). `deltas` must cover epochs `(from, to]` in order — the
/// journal guarantees it; the encoder asserts it in debug builds.
///
/// ```text
/// magic      DELTA_MAGIC                       8 bytes
/// from,to    u64, u64
/// count      u64          (== to - from)
/// per step:  u64 to_epoch
///            u64 batch_len + batch bytes       (a routed-batch payload)
///            diff pairs                        (vertex, new refined)
/// ```
pub fn encode_delta_chain(from: u64, to: u64, deltas: &[&EpochDelta]) -> Vec<u8> {
    debug_assert_eq!(deltas.len() as u64, to - from);
    let mut out = Vec::with_capacity(32 + deltas.len() * 64);
    out.extend_from_slice(DELTA_MAGIC);
    out.extend_from_slice(&from.to_le_bytes());
    out.extend_from_slice(&to.to_le_bytes());
    out.extend_from_slice(&(deltas.len() as u64).to_le_bytes());
    for (i, d) in deltas.iter().enumerate() {
        debug_assert_eq!(d.to_epoch, from + i as u64 + 1);
        out.extend_from_slice(&d.to_epoch.to_le_bytes());
        let batch = encode_batch(&d.batch);
        out.extend_from_slice(&(batch.len() as u64).to_le_bytes());
        out.extend_from_slice(&batch);
        put_pairs(&mut out, &d.diff);
    }
    out
}

/// Parse and validate untrusted delta-chain bytes: magic, declared
/// epoch range, step contiguity, and every embedded routed batch go
/// through the same checks as the rest of the wire. Returns
/// `(from, to, deltas)`.
pub fn decode_delta_chain(bytes: &[u8]) -> Result<(u64, u64, Vec<EpochDelta>)> {
    let mut c = Cursor::new(bytes);
    if c.take(DELTA_MAGIC.len())? != DELTA_MAGIC {
        bail!("not a pico shard delta chain (bad magic)");
    }
    let from = c.u64()?;
    let to = c.u64()?;
    if from >= to {
        bail!("delta chain range {from}..{to} is empty or inverted");
    }
    // each step is at least to_epoch + batch_len + empty batch (two u64
    // counts) + empty diff count — a budget check before any allocation
    let count = c.count(8 + 8 + 16 + 8, "delta step")?;
    if count as u64 != to - from {
        bail!("delta chain declares {count} steps for range {from}..{to}");
    }
    let mut deltas = Vec::with_capacity(count);
    for i in 0..count {
        let to_epoch = c.u64()?;
        if to_epoch != from + i as u64 + 1 {
            bail!(
                "delta step {i} is epoch {to_epoch}, expected {} (chain must be contiguous)",
                from + i as u64 + 1
            );
        }
        let batch_len = c.count(1, "delta batch")?;
        let batch = decode_batch(c.take(batch_len)?)
            .with_context(|| format!("delta step {i} routed batch"))?;
        let diff = take_pairs(&mut c, "delta refined diff")?;
        deltas.push(EpochDelta {
            to_epoch,
            batch,
            diff,
        });
    }
    c.done("delta chain")?;
    Ok((from, to, deltas))
}

/// One vertex crossing shards in a rebalance move: its identity, its
/// committed refined coreness, and its complete adjacency (the partition
/// invariant — an owner holds every arc out of its owned vertices — is
/// what makes the exporting shard's neighbor list authoritative).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HandoffVertex {
    pub id: VertexId,
    pub refined: u32,
    /// Global neighbor ids, strictly ascending (the codec enforces it,
    /// so duplicates and self-loops cannot cross the wire).
    pub neighbors: Vec<VertexId>,
}

/// A decoded, fully validated handoff payload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HandoffPayload {
    /// The exporting shard — an adopter refuses its own exports.
    pub from_shard: u32,
    pub vertices: Vec<HandoffVertex>,
}

/// Serialise an owned-vertex handoff (`SHARDHAND ADOPT` payload):
///
/// ```text
/// magic       HANDOFF_MAGIC                    8 bytes
/// from_shard  u32
/// count       u64
/// per vertex: u32 id, u32 refined,
///             u64 deg + deg × u32 neighbors (strictly ascending)
/// ```
pub fn encode_handoff(from_shard: u32, vertices: &[HandoffVertex]) -> Result<Vec<u8>> {
    if vertices.is_empty() {
        bail!("empty handoff");
    }
    let mut out = Vec::with_capacity(
        20 + vertices.iter().map(|v| 16 + v.neighbors.len() * 4).sum::<usize>(),
    );
    out.extend_from_slice(HANDOFF_MAGIC);
    out.extend_from_slice(&from_shard.to_le_bytes());
    out.extend_from_slice(&(vertices.len() as u64).to_le_bytes());
    for hv in vertices {
        out.extend_from_slice(&hv.id.to_le_bytes());
        out.extend_from_slice(&hv.refined.to_le_bytes());
        out.extend_from_slice(&(hv.neighbors.len() as u64).to_le_bytes());
        for &w in &hv.neighbors {
            out.extend_from_slice(&w.to_le_bytes());
        }
    }
    Ok(out)
}

/// Parse and validate untrusted handoff bytes: neighbor lists must be
/// strictly ascending (no duplicate arcs), free of self-loops, and each
/// refined coreness is capped by the shipped degree — the same bound
/// [`decode_manifest`] enforces for owned vertices.
pub fn decode_handoff(bytes: &[u8]) -> Result<HandoffPayload> {
    let mut c = Cursor::new(bytes);
    if c.take(HANDOFF_MAGIC.len())? != HANDOFF_MAGIC {
        bail!("not a pico shard handoff (bad magic)");
    }
    let from_shard = c.u32()?;
    // each vertex is at least id + refined + an empty-degree count
    let count = c.count(16, "handoff vertex")?;
    if count == 0 {
        bail!("empty handoff");
    }
    let mut vertices = Vec::with_capacity(count);
    let mut last_id: Option<VertexId> = None;
    for _ in 0..count {
        let id = c.u32()?;
        if let Some(prev) = last_id {
            if id <= prev {
                bail!("handoff vertices must be strictly ascending ({prev} then {id})");
            }
        }
        last_id = Some(id);
        let refined = c.u32()?;
        let deg = c.count(4, "handoff neighbors")?;
        if refined as usize > deg {
            bail!("handoff refined {refined} for vertex {id} exceeds its degree {deg}");
        }
        let mut neighbors = Vec::with_capacity(deg);
        for _ in 0..deg {
            let w = c.u32()?;
            if w == id {
                bail!("handoff vertex {id} carries a self-loop");
            }
            if let Some(&prev) = neighbors.last() {
                if w <= prev {
                    bail!("handoff neighbors of {id} must be strictly ascending");
                }
            }
            neighbors.push(w);
        }
        vertices.push(HandoffVertex {
            id,
            refined,
            neighbors,
        });
    }
    c.done("handoff")?;
    Ok(HandoffPayload {
        from_shard,
        vertices,
    })
}

/// A decoded, fully validated shard manifest.
#[derive(Clone, Debug)]
pub struct ShardManifest {
    pub shard_id: u32,
    pub num_shards: u32,
    pub cluster_epoch: u64,
    /// local id → global id (distinctness is checked downstream when the
    /// shard state is rebuilt).
    pub globals: Vec<VertexId>,
    /// Owned local ids.
    pub owned_locals: Vec<u32>,
    /// Committed refined coreness per local id (empty if never refined).
    pub refined: Vec<u32>,
    /// The embedded, already-validated index snapshot.
    pub snapshot: IndexSnapshot,
}

/// Serialise a shard manifest. `snapshot_bytes` must be a
/// [`crate::shard::snapshot::encode`] payload for the same shard.
pub fn encode_manifest(
    shard_id: u32,
    num_shards: u32,
    cluster_epoch: u64,
    globals: &[VertexId],
    owned_locals: &[u32],
    refined: &[u32],
    snapshot_bytes: &[u8],
) -> Vec<u8> {
    let mut out = Vec::with_capacity(
        8 + 8
            + 8
            + 32
            + globals.len() * 4
            + owned_locals.len() * 4
            + refined.len() * 4
            + snapshot_bytes.len(),
    );
    out.extend_from_slice(MANIFEST_MAGIC);
    out.extend_from_slice(&shard_id.to_le_bytes());
    out.extend_from_slice(&num_shards.to_le_bytes());
    out.extend_from_slice(&cluster_epoch.to_le_bytes());
    out.extend_from_slice(&(globals.len() as u64).to_le_bytes());
    out.extend_from_slice(&(owned_locals.len() as u64).to_le_bytes());
    out.extend_from_slice(&(refined.len() as u64).to_le_bytes());
    out.extend_from_slice(&(snapshot_bytes.len() as u64).to_le_bytes());
    for &v in globals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    for &l in owned_locals {
        out.extend_from_slice(&l.to_le_bytes());
    }
    for &r in refined {
        out.extend_from_slice(&r.to_le_bytes());
    }
    out.extend_from_slice(snapshot_bytes);
    out
}

/// Parse and validate untrusted manifest bytes (including the embedded
/// snapshot's full structural + invariant validation).
pub fn decode_manifest(bytes: &[u8]) -> Result<ShardManifest> {
    let mut c = Cursor::new(bytes);
    if c.take(MANIFEST_MAGIC.len())? != MANIFEST_MAGIC {
        bail!("not a pico shard manifest (bad magic)");
    }
    let shard_id = c.u32()?;
    let num_shards = c.u32()?;
    if num_shards == 0 || shard_id >= num_shards {
        bail!("shard id {shard_id} out of range for {num_shards} shards");
    }
    let cluster_epoch = c.u64()?;
    let globals_len = c.u64()? as usize;
    let owned_len = c.u64()? as usize;
    let refined_len = c.u64()? as usize;
    let snapshot_len = c.u64()? as usize;
    // exact byte-budget check before any allocation
    let expected = globals_len
        .checked_mul(4)
        .and_then(|b| b.checked_add(owned_len.checked_mul(4)?))
        .and_then(|b| b.checked_add(refined_len.checked_mul(4)?))
        .and_then(|b| b.checked_add(snapshot_len));
    match expected {
        Some(want) if want == c.remaining() => {}
        _ => bail!(
            "manifest size mismatch: header declares {globals_len}/{owned_len}/{refined_len}/{snapshot_len} but {} bytes remain",
            c.remaining()
        ),
    }
    let mut globals = Vec::with_capacity(globals_len);
    for _ in 0..globals_len {
        globals.push(c.u32()?);
    }
    let mut owned_locals = Vec::with_capacity(owned_len);
    for _ in 0..owned_len {
        let l = c.u32()?;
        if l as usize >= globals_len {
            bail!("owned local {l} out of range (n={globals_len})");
        }
        owned_locals.push(l);
    }
    let mut refined = Vec::with_capacity(refined_len);
    for _ in 0..refined_len {
        refined.push(c.u32()?);
    }
    if !refined.is_empty() && refined.len() != globals_len {
        bail!(
            "refined length {} != vertex count {globals_len}",
            refined.len()
        );
    }
    let snapshot =
        snapshot::decode(c.take(snapshot_len)?).context("embedded shard snapshot")?;
    c.done("manifest")?;
    if snapshot.graph.num_vertices() != globals_len {
        bail!(
            "snapshot has {} vertices but the id table lists {globals_len}",
            snapshot.graph.num_vertices()
        );
    }
    // refined values for owned vertices are exact global corenesses and
    // can never exceed the vertex's (complete, by partition invariant)
    // local degree
    for &l in &owned_locals {
        if let Some(&r) = refined.get(l as usize) {
            let d = snapshot.graph.degree(l);
            if r > d {
                bail!("refined[{l}] = {r} exceeds degree {d}");
            }
        }
    }
    Ok(ShardManifest {
        shard_id,
        num_shards,
        cluster_epoch,
        globals,
        owned_locals,
        refined,
        snapshot,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::examples;
    use crate::service::index::CoreIndex;
    use crate::shard::snapshot::encode_index;

    #[test]
    fn batch_and_pairs_round_trip() {
        let batch = RoutedBatch {
            new_owned: vec![7, 9],
            edits: vec![
                (EdgeEdit::Insert(1, 9), true),
                (EdgeEdit::Delete(3, 4), false),
            ],
        };
        assert_eq!(decode_batch(&encode_batch(&batch)).unwrap(), batch);
        let pairs = vec![(0u32, 3u32), (17, 0)];
        assert_eq!(decode_pairs(&encode_pairs(&pairs)).unwrap(), pairs);
        assert_eq!(decode_u32s(&encode_u32s(&[5, 6])).unwrap(), vec![5, 6]);
        let init = RefineInit {
            owned_est: pairs,
            ghosts: vec![2],
            arcs: 10,
            boundary_arcs: 4,
        };
        assert_eq!(decode_refine_init(&encode_refine_init(&init)).unwrap(), init);
    }

    #[test]
    fn corrupt_payloads_are_rejected() {
        let good = encode_batch(&RoutedBatch {
            new_owned: vec![1],
            edits: vec![(EdgeEdit::Insert(0, 1), true)],
        });
        for cut in 0..good.len() {
            assert!(decode_batch(&good[..cut]).is_err(), "cut at {cut}");
        }
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(decode_batch(&trailing).is_err());
        // a count far beyond the payload must fail before allocating
        let mut huge = good.clone();
        huge[0..8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_batch(&huge).is_err());
        // self-loop edit refused
        let evil = encode_batch(&RoutedBatch {
            new_owned: vec![],
            edits: vec![(EdgeEdit::Insert(3, 3), true)],
        });
        assert!(decode_batch(&evil).is_err());
        assert!(decode_pairs(&[1, 2, 3]).is_err());
        assert!(decode_manifest(b"NOTAMANIFESTxxxx").is_err());
    }

    #[test]
    fn delta_chains_round_trip_and_validate() {
        let deltas = [
            EpochDelta {
                to_epoch: 4,
                batch: RoutedBatch {
                    new_owned: vec![9],
                    edits: vec![(EdgeEdit::Insert(1, 9), true)],
                },
                diff: vec![(1, 3), (9, 1)],
            },
            EpochDelta {
                to_epoch: 5,
                batch: RoutedBatch::default(),
                diff: vec![],
            },
        ];
        let refs: Vec<&EpochDelta> = deltas.iter().collect();
        let bytes = encode_delta_chain(3, 5, &refs);
        let (from, to, got) = decode_delta_chain(&bytes).unwrap();
        assert_eq!((from, to), (3, 5));
        assert_eq!(got, deltas);

        // truncations at every length never panic, always reject
        for cut in 0..bytes.len() {
            assert!(decode_delta_chain(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        // trailing garbage rejected
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(decode_delta_chain(&trailing).is_err());
        // bad magic
        assert!(decode_delta_chain(b"NOTADELTAxxxxxxxxxxxxxxxxxxxxxxx").is_err());
        // inverted / empty ranges
        let mut inverted = bytes.clone();
        inverted[8..16].copy_from_slice(&9u64.to_le_bytes());
        assert!(decode_delta_chain(&inverted).is_err());
        // a step count far beyond the payload fails before allocating
        let mut huge = bytes.clone();
        huge[24..32].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_delta_chain(&huge).is_err());
        // non-contiguous step epoch rejected
        let mut skewed = bytes.clone();
        skewed[32..40].copy_from_slice(&9u64.to_le_bytes());
        assert!(decode_delta_chain(&skewed).is_err());
        // a corrupt embedded batch (self-loop) is refused
        let evil = [EpochDelta {
            to_epoch: 1,
            batch: RoutedBatch {
                new_owned: vec![],
                edits: vec![(EdgeEdit::Insert(3, 3), true)],
            },
            diff: vec![],
        }];
        let refs: Vec<&EpochDelta> = evil.iter().collect();
        assert!(decode_delta_chain(&encode_delta_chain(0, 1, &refs)).is_err());
    }

    #[test]
    fn handoff_round_trips_and_validates() {
        let vs = vec![
            HandoffVertex {
                id: 3,
                refined: 2,
                neighbors: vec![1, 4, 9],
            },
            HandoffVertex {
                id: 7,
                refined: 0,
                neighbors: vec![],
            },
        ];
        let bytes = encode_handoff(1, &vs).unwrap();
        let p = decode_handoff(&bytes).unwrap();
        assert_eq!(p.from_shard, 1);
        assert_eq!(p.vertices, vs);
        // truncations never panic, always reject
        for cut in 0..bytes.len() {
            assert!(decode_handoff(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(decode_handoff(&trailing).is_err());
        assert!(decode_handoff(b"NOTAHANDOFFxxxxxxxxxxxxx").is_err());
        assert!(encode_handoff(0, &[]).is_err(), "empty handoff");
        // a count far beyond the payload fails before allocating
        let mut huge = bytes.clone();
        huge[12..20].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_handoff(&huge).is_err());
        // refined above the shipped degree
        let evil = encode_handoff(
            0,
            &[HandoffVertex {
                id: 1,
                refined: 5,
                neighbors: vec![2],
            }],
        )
        .unwrap();
        assert!(decode_handoff(&evil).is_err());
        // self-loops and unsorted neighbor lists rejected
        let evil = encode_handoff(
            0,
            &[HandoffVertex {
                id: 1,
                refined: 0,
                neighbors: vec![1],
            }],
        )
        .unwrap();
        assert!(decode_handoff(&evil).is_err());
        // vertices out of ascending order rejected
        let evil = {
            let a = HandoffVertex {
                id: 9,
                refined: 0,
                neighbors: vec![],
            };
            let b = HandoffVertex {
                id: 3,
                refined: 0,
                neighbors: vec![],
            };
            encode_handoff(0, &[a, b]).unwrap()
        };
        assert!(decode_handoff(&evil).is_err());
    }

    #[test]
    fn manifest_round_trips_and_validates() {
        let g = examples::g1();
        let idx = CoreIndex::new("m/shard0", &g);
        let snap_bytes = encode_index(&idx);
        let n = g.num_vertices();
        let globals: Vec<u32> = (0..n as u32).collect();
        let owned: Vec<u32> = (0..n as u32).collect();
        let refined: Vec<u32> = idx.snapshot().core.clone();
        let bytes = encode_manifest(0, 2, 5, &globals, &owned, &refined, &snap_bytes);
        let m = decode_manifest(&bytes).unwrap();
        assert_eq!(m.shard_id, 0);
        assert_eq!(m.num_shards, 2);
        assert_eq!(m.cluster_epoch, 5);
        assert_eq!(m.globals, globals);
        assert_eq!(m.owned_locals, owned);
        assert_eq!(m.refined, refined);
        assert_eq!(m.snapshot.name, "m/shard0");
        // out-of-range shard id
        assert!(decode_manifest(&encode_manifest(2, 2, 0, &globals, &owned, &refined, &snap_bytes)).is_err());
        // owned local beyond the vertex count
        assert!(decode_manifest(&encode_manifest(0, 2, 0, &globals, &[99], &refined, &snap_bytes)).is_err());
        // refined above the degree cap
        let mut evil = refined.clone();
        evil[0] = 100;
        assert!(decode_manifest(&encode_manifest(0, 2, 0, &globals, &owned, &evil, &snap_bytes)).is_err());
        // truncations never panic
        for cut in [0, 8, 20, bytes.len() / 2, bytes.len() - 1] {
            assert!(decode_manifest(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    }
}
