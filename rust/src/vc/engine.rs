//! The vertex-centric execution engine: materialised frontiers, dynamic
//! operator dispatch, one BSP launch per operator — the Gunrock execution
//! model, overheads included.

use super::operators::{AdvanceOp, FilterOp};
use crate::engine::frontier::NextFrontier;
use crate::engine::metrics::Metrics;
use crate::engine::spmd::run_spmd;
use crate::graph::CsrGraph;
use std::sync::atomic::AtomicUsize;
#[cfg(test)]
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

/// A program iterates operator sequences until its frontier drains.
pub trait VcProgram: Sync {
    /// The initial frontier.
    fn init(&self, g: &CsrGraph) -> Vec<u32>;

    /// One iteration: given the engine handle and the current frontier,
    /// produce the next frontier. Returns `None` to terminate early.
    fn step(&self, eng: &VcStep<'_>, frontier: &[u32]) -> Option<Vec<u32>>;
}

/// Engine view handed to programs inside one iteration: runs operators as
/// individual launches over the worker pool.
pub struct VcStep<'a> {
    pub g: &'a CsrGraph,
    pub metrics: &'a Metrics,
    threads: usize,
}

impl VcStep<'_> {
    /// `advance`: visit all out-edges of the frontier, collecting marked
    /// destinations (deduplicated) into the output frontier.
    pub fn advance(&self, frontier: &[u32], op: &dyn AdvanceOp) -> Vec<u32> {
        let out = NextFrontier::new(self.g.num_vertices());
        let cursor = AtomicUsize::new(0);
        run_spmd(self.threads, |ctx| {
            let mv = self.metrics.view(ctx.tid);
            for range in ctx.dynamic_chunks(frontier.len(), 32, &cursor) {
                for &v in &frontier[range] {
                    for &u in self.g.neighbors(v) {
                        mv.edge_accesses(1);
                        if op.visit_edge(v, u, ctx.tid) {
                            out.push(u);
                        }
                    }
                }
            }
        });
        out.take()
    }

    /// `filter`: compact the vertices of `domain` that satisfy `op`.
    pub fn filter(&self, domain: &[u32], op: &dyn FilterOp) -> Vec<u32> {
        let out = NextFrontier::new(self.g.num_vertices());
        let cursor = AtomicUsize::new(0);
        run_spmd(self.threads, |ctx| {
            for range in ctx.dynamic_chunks(domain.len(), 256, &cursor) {
                for &v in &domain[range] {
                    if op.keep(v, ctx.tid) {
                        out.push(v);
                    }
                }
            }
        });
        out.take()
    }

    /// `filter` over the whole vertex set.
    pub fn filter_all(&self, op: &dyn FilterOp) -> Vec<u32> {
        let all: Vec<u32> = (0..self.g.num_vertices() as u32).collect();
        self.filter(&all, op)
    }
}

/// The framework driver.
pub struct VcEngine {
    pub threads: usize,
}

impl VcEngine {
    pub fn new(threads: usize) -> Self {
        Self { threads }
    }

    /// Run a program to completion; returns the number of iterations.
    pub fn run(&self, g: &CsrGraph, program: &dyn VcProgram, metrics: &Metrics) -> usize {
        let step = VcStep {
            g,
            metrics,
            threads: self.threads,
        };
        let frontier = Mutex::new(Arc::new(program.init(g)));
        let mut iterations = 0usize;
        loop {
            let current = frontier.lock().unwrap().clone();
            if current.is_empty() {
                break;
            }
            iterations += 1;
            match program.step(&step, &current) {
                Some(next) => *frontier.lock().unwrap() = Arc::new(next),
                None => break,
            }
        }
        iterations
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::examples;
    use std::sync::atomic::AtomicU32;

    /// BFS levels via the framework — exercises advance + dedup.
    struct Bfs {
        dist: Vec<AtomicU32>,
    }

    impl VcProgram for Bfs {
        fn init(&self, _g: &CsrGraph) -> Vec<u32> {
            self.dist[0].store(0, Ordering::Relaxed);
            vec![0]
        }

        fn step(&self, eng: &VcStep<'_>, frontier: &[u32]) -> Option<Vec<u32>> {
            let next = eng.advance(frontier, &|src: u32, dst: u32, _| {
                let d = self.dist[src as usize].load(Ordering::Relaxed);
                // relax once: only unvisited vertices enter the frontier
                self.dist[dst as usize]
                    .compare_exchange(u32::MAX, d + 1, Ordering::Relaxed, Ordering::Relaxed)
                    .is_ok()
            });
            Some(next)
        }
    }

    #[test]
    fn bfs_on_path() {
        let g = examples::path(6);
        let prog = Bfs {
            dist: (0..6).map(|_| AtomicU32::new(u32::MAX)).collect(),
        };
        let eng = VcEngine::new(2);
        let m = Metrics::disabled(2);
        let iters = eng.run(&g, &prog, &m);
        let dist: Vec<u32> = prog.dist.iter().map(|d| d.load(Ordering::Relaxed)).collect();
        assert_eq!(dist, vec![0, 1, 2, 3, 4, 5]);
        assert!(iters >= 5);
    }

    #[test]
    fn filter_compacts() {
        let g = examples::g1();
        let m = Metrics::disabled(2);
        let step = VcStep {
            g: &g,
            metrics: &m,
            threads: 2,
        };
        let mut evens = step.filter_all(&super::super::operators::FilterFn(|v: u32, _| v % 2 == 0));
        evens.sort_unstable();
        assert_eq!(evens, vec![0, 2, 4]);
    }
}
