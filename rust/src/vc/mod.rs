//! A generic vertex-centric graph-processing framework — the **Gunrock
//! analog** for Table IV's system-level baseline.
//!
//! Gunrock [22] expresses algorithms as sequences of *advance* / *filter*
//! operators over frontiers. That generality costs: operators are
//! dispatched dynamically, every frontier is materialised, and each
//! operator is its own launch. This module reproduces exactly that
//! overhead class (deliberately — the point of the Table IV column is to
//! quantify what hand-fused kernels save), then implements the k-core
//! peel on top ([`vc_peel::VcPeel`]).

pub mod engine;
pub mod operators;
pub mod vc_peel;

pub use engine::{VcEngine, VcProgram, VcStep};
pub use operators::{AdvanceOp, FilterFn, FilterOp};
pub use vc_peel::VcPeel;
