//! The k-core peel expressed in the vertex-centric framework — the
//! Gunrock-style baseline of Table IV. Logic matches GPP (Algorithm 3);
//! the difference is purely *where* it runs: generic operators with
//! materialised frontiers and dynamic dispatch instead of hand-fused
//! scan/scatter kernels.

use super::engine::{VcEngine, VcProgram, VcStep};
use super::operators::FilterFn;
use crate::core::traits::{DecompositionResult, Decomposer, Paradigm};
use crate::engine::atomics::AtomicCoreArray;
use crate::engine::metrics::Metrics;
use crate::graph::CsrGraph;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};

/// GPP on the vertex-centric framework.
#[derive(Clone, Copy, Debug, Default)]
pub struct VcPeel;

struct PeelProgram {
    deg: AtomicCoreArray,
    core: AtomicCoreArray,
    rem: Vec<AtomicBool>,
    k: AtomicU32,
    removed: AtomicUsize,
    rounds: AtomicUsize,
}

impl VcProgram for PeelProgram {
    fn init(&self, g: &CsrGraph) -> Vec<u32> {
        // sentinel frontier; real work starts in step()
        (0..g.num_vertices().min(1) as u32).collect()
    }

    fn step(&self, eng: &VcStep<'_>, _frontier: &[u32]) -> Option<Vec<u32>> {
        let n = eng.g.num_vertices();
        if self.removed.load(Ordering::Acquire) >= n {
            return None;
        }
        let k = self.k.load(Ordering::Acquire);

        // filter: locate this round's frontier {!rem && deg <= k}
        let frontier = eng.filter_all(&FilterFn(|v: u32, _| {
            let v = v as usize;
            !self.rem[v].load(Ordering::Relaxed) && self.deg.load(v) <= k
        }));

        if frontier.is_empty() {
            self.k.fetch_add(1, Ordering::AcqRel);
            // keep a sentinel frontier so the driver continues
            return Some(vec![0]);
        }

        // compute: mark removed, record coreness
        for &v in &frontier {
            self.rem[v as usize].store(true, Ordering::Relaxed);
            self.core.store(v as usize, k);
        }
        self.removed.fetch_add(frontier.len(), Ordering::AcqRel);
        self.rounds.fetch_add(1, Ordering::Relaxed);

        // advance: decrement residual neighbors
        let _ = eng.advance(&frontier, &|_src: u32, dst: u32, _tid| {
            if !self.rem[dst as usize].load(Ordering::Relaxed) {
                self.deg.cell(dst as usize).fetch_sub(1, Ordering::Relaxed);
            }
            false // peel does not propagate a frontier through advance
        });

        Some(vec![0]) // sentinel: loop until all removed
    }
}

impl Decomposer for VcPeel {
    fn name(&self) -> &'static str {
        "VC-Peel(Gunrock)"
    }

    fn paradigm(&self) -> Paradigm {
        Paradigm::Peel
    }

    fn decompose_with(&self, g: &CsrGraph, threads: usize, metrics_on: bool) -> DecompositionResult {
        let n = g.num_vertices();
        let metrics = Metrics::new(threads, metrics_on);
        if n == 0 {
            return DecompositionResult {
                core: vec![],
                iterations: 0,
                launches: 0,
                metrics: metrics.snapshot(),
            };
        }
        let prog = PeelProgram {
            deg: AtomicCoreArray::from_vec(g.degrees()),
            core: AtomicCoreArray::zeros(n),
            rem: (0..n).map(|_| AtomicBool::new(false)).collect(),
            k: AtomicU32::new(0),
            removed: AtomicUsize::new(0),
            rounds: AtomicUsize::new(0),
        };
        let engine = VcEngine::new(threads);
        let launches = engine.run(g, &prog, &metrics);
        DecompositionResult {
            core: prog.core.to_vec(),
            iterations: prog.rounds.load(Ordering::Relaxed),
            launches,
            metrics: metrics.snapshot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::{examples, gen};

    #[test]
    fn g1_matches_paper() {
        let r = VcPeel.decompose_with(&examples::g1(), 2, false);
        assert_eq!(r.core, examples::g1_coreness());
    }

    #[test]
    fn matches_bz_on_random_graphs() {
        for seed in 0..3 {
            let g = gen::erdos_renyi(250, 1000, seed);
            assert_eq!(VcPeel.decompose_with(&g, 2, false).core, bz_coreness(&g), "seed={seed}");
        }
    }

    #[test]
    fn matches_bz_on_powerlaw() {
        let g = gen::barabasi_albert(500, 3, 5);
        assert_eq!(VcPeel.decompose_with(&g, 2, false).core, bz_coreness(&g));
    }

    #[test]
    fn isolated_vertices() {
        let g = crate::graph::GraphBuilder::new(3).build("iso");
        assert_eq!(VcPeel.decompose_with(&g, 1, false).core, vec![0; 3]);
    }
}
