//! Operator interfaces of the vertex-centric framework — the Gunrock
//! `advance` / `filter` pair, object-safe so programs compose them
//! dynamically (which is precisely the system overhead the framework
//! column of Table IV measures).

/// Per-edge visitor of an `advance` over the frontier: called for every
/// edge (src, dst) with src in the frontier; returns `true` when `dst`
/// should enter the operator's output frontier.
pub trait AdvanceOp: Sync {
    fn visit_edge(&self, src: u32, dst: u32, tid: usize) -> bool;
}

/// Per-vertex predicate of a `filter` pass over a domain.
pub trait FilterOp: Sync {
    fn keep(&self, v: u32, tid: usize) -> bool;
}

/// Blanket impls so closures can be used directly.
impl<F> AdvanceOp for F
where
    F: Fn(u32, u32, usize) -> bool + Sync,
{
    fn visit_edge(&self, src: u32, dst: u32, tid: usize) -> bool {
        self(src, dst, tid)
    }
}

pub struct FilterFn<F>(pub F);

impl<F> FilterOp for FilterFn<F>
where
    F: Fn(u32, usize) -> bool + Sync,
{
    fn keep(&self, v: u32, tid: usize) -> bool {
        (self.0)(v, tid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closure_as_advance() {
        let op = |src: u32, dst: u32, _tid: usize| src < dst;
        assert!(op.visit_edge(1, 2, 0));
        assert!(!AdvanceOp::visit_edge(&op, 3, 2, 0));
    }

    #[test]
    fn filter_fn_wrapper() {
        let f = FilterFn(|v: u32, _| v % 2 == 0);
        assert!(f.keep(4, 0));
        assert!(!f.keep(5, 0));
    }
}
