//! Core-hierarchy queries — the §I application layer the paper motivates
//! (community/engagement analysis, degeneracy ordering for clique
//! finding [3], k-core subgraph extraction for clustering [2]).

use crate::core::bz::bz_coreness;
use crate::graph::{CsrGraph, GraphBuilder, VertexId};

/// A computed core decomposition with query helpers.
#[derive(Clone, Debug)]
pub struct CoreHierarchy {
    pub core: Vec<u32>,
    pub k_max: u32,
}

impl CoreHierarchy {
    pub fn from_coreness(core: Vec<u32>) -> Self {
        let k_max = core.iter().copied().max().unwrap_or(0);
        Self { core, k_max }
    }

    pub fn compute(g: &CsrGraph) -> Self {
        Self::from_coreness(bz_coreness(g))
    }

    /// Vertices of the k-core.
    pub fn k_core_vertices(&self, k: u32) -> Vec<VertexId> {
        (0..self.core.len() as VertexId)
            .filter(|&v| self.core[v as usize] >= k)
            .collect()
    }

    /// Size of each k-shell (vertices with coreness exactly k).
    pub fn shell_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.k_max as usize + 1];
        for &c in &self.core {
            sizes[c as usize] += 1;
        }
        sizes
    }

    /// Induced subgraph of the k-core, with a vertex-id mapping back to
    /// the original graph.
    pub fn extract_k_core(&self, g: &CsrGraph, k: u32) -> (CsrGraph, Vec<VertexId>) {
        let members = self.k_core_vertices(k);
        let mut remap = vec![u32::MAX; g.num_vertices()];
        for (new, &old) in members.iter().enumerate() {
            remap[old as usize] = new as u32;
        }
        let mut b = GraphBuilder::new(members.len());
        for &old in &members {
            for &u in g.neighbors(old) {
                let ru = remap[u as usize];
                if ru != u32::MAX && remap[old as usize] < ru {
                    b.add_edge(remap[old as usize], ru);
                }
            }
        }
        (b.build(format!("{}-{}core", g.name, k)), members)
    }

    /// Degeneracy ordering (peel order): vertices sorted by coreness,
    /// ties by id — the ordering used to linearise clique enumeration
    /// (paper ref [3]). The graph's degeneracy is `k_max`.
    pub fn degeneracy_ordering(&self) -> Vec<VertexId> {
        let mut order: Vec<VertexId> = (0..self.core.len() as VertexId).collect();
        order.sort_by_key(|&v| (self.core[v as usize], v));
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::examples;

    #[test]
    fn g1_hierarchy() {
        let g = examples::g1();
        let h = CoreHierarchy::compute(&g);
        assert_eq!(h.k_max, 2);
        assert_eq!(h.k_core_vertices(2), vec![2, 3, 4, 5]);
        assert_eq!(h.shell_sizes(), vec![0, 2, 4]);
    }

    #[test]
    fn extract_two_core_of_g1() {
        let g = examples::g1();
        let h = CoreHierarchy::compute(&g);
        let (sub, members) = h.extract_k_core(&g, 2);
        assert_eq!(members, vec![2, 3, 4, 5]);
        assert_eq!(sub.num_vertices(), 4);
        // the 2-core of G1 keeps edges {23,24,34,35,45} -> 5 edges
        assert_eq!(sub.num_edges(), 5);
        assert!(sub.degrees().iter().all(|&d| d >= 2));
    }

    #[test]
    fn degeneracy_ordering_is_monotone_in_coreness() {
        let g = examples::g1();
        let h = CoreHierarchy::compute(&g);
        let order = h.degeneracy_ordering();
        for w in order.windows(2) {
            assert!(h.core[w[0] as usize] <= h.core[w[1] as usize]);
        }
        assert_eq!(order.len(), 6);
    }

    #[test]
    fn k_core_minimum_degree_property() {
        let g = crate::graph::gen::barabasi_albert(500, 4, 7);
        let h = CoreHierarchy::compute(&g);
        for k in [2u32, 3, 4] {
            let (sub, _) = h.extract_k_core(&g, k);
            if sub.num_vertices() > 0 {
                assert!(sub.degrees().iter().all(|&d| d >= k), "k={k}");
            }
        }
    }

    #[test]
    fn empty_core_extraction() {
        let g = examples::path(5);
        let h = CoreHierarchy::compute(&g);
        let (sub, members) = h.extract_k_core(&g, 5);
        assert!(members.is_empty());
        assert_eq!(sub.num_vertices(), 0);
    }
}
