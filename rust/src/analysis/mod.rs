//! Workload analysis — the instrumentation behind Fig. 3 (multi-access
//! proportions of the Index2core paradigm) and the under-core census that
//! motivates the assertion method (§III.A).

pub mod activation;
pub mod hierarchy;
pub mod undercore;

pub use activation::{activation_profile, ActivationProfile};
pub use hierarchy::CoreHierarchy;
pub use undercore::{undercore_census, UndercoreCensus};
