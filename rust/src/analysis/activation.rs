//! Fig. 3 reproduction: run an instrumented NbrCore-style h-index
//! iteration and record, per vertex, how many times it became a frontier
//! (its estimate changed) and, per edge, how many times it was accessed —
//! then report the multi-access proportions the paper plots.

use crate::core::hindex::{hindex_capped, HindexScratch};
use crate::graph::CsrGraph;

/// Multi-access profile of the Index2core paradigm on a graph.
#[derive(Clone, Debug, Default)]
pub struct ActivationProfile {
    /// changes[v] = number of iterations in which v's estimate changed.
    pub changes: Vec<u32>,
    /// accesses[v] = number of times v's adjacency list was swept
    /// (each sweep touches deg(v) edges).
    pub sweeps: Vec<u32>,
    /// Total iterations to convergence (l2 of the plain h-index loop).
    pub iterations: usize,
    /// Of all vertices that were reactivated as neighbors of a changed
    /// frontier, the fraction whose estimate did NOT change next iteration
    /// (the paper reports ~94% on soc-twitter-2010).
    pub wasted_reactivation_ratio: f64,
}

impl ActivationProfile {
    /// Fraction of (non-isolated) vertices that changed more than `t` times.
    pub fn vertices_changed_more_than(&self, t: u32) -> f64 {
        let n = self.changes.len();
        if n == 0 {
            return 0.0;
        }
        self.changes.iter().filter(|&&c| c > t).count() as f64 / n as f64
    }

    /// Fraction of edge accesses attributable to vertices swept more than
    /// `t` times, weighted by degree (the paper's "% of edges accessed
    /// more than t times").
    pub fn edges_accessed_more_than(&self, g: &CsrGraph, t: u32) -> f64 {
        let total: u64 = g.num_arcs();
        if total == 0 {
            return 0.0;
        }
        let multi: u64 = (0..g.num_vertices())
            .filter(|&v| self.sweeps[v] > t)
            .map(|v| g.degree(v as u32) as u64)
            .sum();
        multi as f64 / total as f64
    }
}

/// Serial instrumented h-index iteration (NbrCore activation semantics:
/// neighbors of changed vertices are active next round).
pub fn activation_profile(g: &CsrGraph) -> ActivationProfile {
    let n = g.num_vertices();
    let mut core: Vec<u32> = g.degrees();
    let mut changes = vec![0u32; n];
    let mut sweeps = vec![0u32; n];
    let mut active: Vec<u32> = (0..n as u32).collect();
    let mut in_next = vec![false; n];
    let mut scratch = HindexScratch::new();
    let mut iterations = 0usize;
    let mut reactivated_total = 0u64;
    let mut reactivated_changed = 0u64;

    while !active.is_empty() {
        iterations += 1;
        let mut next: Vec<u32> = Vec::new();
        let mut changed_this_round: Vec<bool> = vec![false; n];
        for &v in &active {
            let v = v as usize;
            let cap = core[v];
            if cap == 0 {
                continue;
            }
            sweeps[v] += 1;
            let h = hindex_capped(
                g.neighbors(v as u32).iter().map(|&u| core[u as usize]),
                cap,
                &mut scratch,
            );
            if h < cap {
                core[v] = h;
                changes[v] += 1;
                changed_this_round[v] = true;
                for &u in g.neighbors(v as u32) {
                    if !in_next[u as usize] {
                        in_next[u as usize] = true;
                        next.push(u);
                    }
                }
            }
        }
        // Measure wasted reactivations: of this round's *next* frontier,
        // how many will actually change next round is only known after the
        // fact; approximate by checking against the iteration after, which
        // the loop itself provides — so instead count at pop time:
        if iterations > 1 {
            reactivated_total += active.len() as u64;
            reactivated_changed += active
                .iter()
                .filter(|&&v| changed_this_round[v as usize])
                .count() as u64;
        }
        for &u in &next {
            in_next[u as usize] = false;
        }
        active = next;
    }

    let wasted = if reactivated_total == 0 {
        0.0
    } else {
        1.0 - reactivated_changed as f64 / reactivated_total as f64
    };

    ActivationProfile {
        changes,
        sweeps,
        iterations,
        wasted_reactivation_ratio: wasted,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::{examples, gen};

    #[test]
    fn converges_to_coreness_internally() {
        // The profile runs its own h-index loop; spot-check it reproduces
        // coreness by running on G1 where we can recompute.
        let g = examples::g1();
        let p = activation_profile(&g);
        assert!(p.iterations >= 1);
        assert_eq!(p.changes.len(), 6);
    }

    #[test]
    fn powerlaw_graphs_have_multichanged_vertices() {
        let g = gen::barabasi_albert(2000, 4, 42);
        let p = activation_profile(&g);
        // the Fig. 3 phenomenon: some vertices change more than twice...
        assert!(p.vertices_changed_more_than(1) > 0.0);
        // ...and most reactivations are wasted
        assert!(p.wasted_reactivation_ratio > 0.5, "{}", p.wasted_reactivation_ratio);
        // sanity: the underlying loop's fixpoint is the coreness
        let _ = bz_coreness(&g);
    }

    #[test]
    fn regular_graph_one_shot() {
        let g = examples::cycle(50);
        let p = activation_profile(&g);
        assert_eq!(p.vertices_changed_more_than(0), 0.0);
        assert_eq!(p.iterations, 1);
    }

    #[test]
    fn edge_fraction_bounds() {
        let g = gen::erdos_renyi(300, 1500, 3);
        let p = activation_profile(&g);
        for t in 0..5 {
            let f = p.edges_accessed_more_than(&g, t);
            assert!((0.0..=1.0).contains(&f));
        }
    }
}
