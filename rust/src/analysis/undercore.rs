//! Under-core census (§III.A): during a serial peel, count how many
//! vertices become *under-core* — residual degree strictly below the level
//! k at which they are removed — and how many extra atomic operations the
//! non-assertion baselines would spend on them (the Fig. 4 arithmetic:
//! `2(n−m)` avoidable atomics per under-core vertex).

use crate::graph::CsrGraph;

/// Result of the census.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct UndercoreCensus {
    /// Number of vertices removed with residual degree < their coreness k.
    pub undercore_vertices: u64,
    /// Total decrements that drove residual degrees below the level —
    /// each costs one extra sub + one corrective add in PP-dyn (Fig. 4a).
    pub below_floor_decrements: u64,
    /// Total (would-be) atomic decrements of the peel.
    pub total_decrements: u64,
}

impl UndercoreCensus {
    /// The avoidable atomics of Fig. 4: sub below floor + corrective add.
    pub fn avoidable_atomics(&self) -> u64 {
        2 * self.below_floor_decrements
    }
}

/// Serial peel that tracks under-core events exactly.
pub fn undercore_census(g: &CsrGraph) -> UndercoreCensus {
    let n = g.num_vertices();
    let mut deg: Vec<i64> = (0..n).map(|v| g.degree(v as u32) as i64).collect();
    let mut removed = vec![false; n];
    let mut census = UndercoreCensus::default();
    let mut remaining = n;
    let mut k: i64 = 0;
    while remaining > 0 {
        // frontier at this k
        let frontier: Vec<usize> = (0..n)
            .filter(|&v| !removed[v] && deg[v] <= k)
            .collect();
        if frontier.is_empty() {
            k += 1;
            continue;
        }
        for &v in &frontier {
            removed[v] = true;
            remaining -= 1;
            if deg[v] < k {
                census.undercore_vertices += 1;
            }
        }
        for &v in &frontier {
            for &u in g.neighbors(v as u32) {
                let u = u as usize;
                if !removed[u] {
                    census.total_decrements += 1;
                    deg[u] -= 1;
                    if deg[u] < k {
                        census.below_floor_decrements += 1;
                    }
                }
            }
        }
    }
    census
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{examples, gen};

    #[test]
    fn g1_has_undercore_vertices() {
        // Fig. 2: v3 and v5 end up under-core in the third iteration.
        let c = undercore_census(&examples::g1());
        assert!(c.undercore_vertices >= 1);
        assert!(c.total_decrements > 0);
    }

    #[test]
    fn path_has_no_undercore() {
        // Peeling a path removes endpoints with deg exactly 1 = k.
        let c = undercore_census(&examples::path(20));
        assert_eq!(c.undercore_vertices, 0);
    }

    #[test]
    fn clique_chain_heavy_undercore() {
        let (g, _) = gen::nested_cliques(3, 5, 5);
        let c = undercore_census(&g);
        // removing a clique level floods the rest below k
        assert!(c.below_floor_decrements > 0);
        assert_eq!(c.avoidable_atomics(), 2 * c.below_floor_decrements);
    }
}
