//! Deterministic pseudo-random number generation.
//!
//! The offline environment has no `rand` crate, so we carry our own
//! SplitMix64 (seeding / stream splitting) and xoshiro256** (bulk
//! generation). Both are well-studied, tiny, and — critically for the
//! benchmark suite — fully deterministic across platforms, so every
//! synthetic dataset is reproducible from its seed.

/// SplitMix64: used to expand a user seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed. Any seed (including 0) is fine:
    /// state is expanded through SplitMix64 per the xoshiro authors'
    /// recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (for per-thread / per-partition use).
    pub fn split(&mut self, stream: u64) -> Rng {
        let mut sm = SplitMix64::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407));
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased enough
    /// for workload generation; bound ≥ 1).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound >= 1);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from cumulative weights (workload mixes).
    pub fn weighted(&mut self, cumulative: &[f64]) -> usize {
        let total = *cumulative.last().expect("non-empty weights");
        let x = self.f64() * total;
        match cumulative.binary_search_by(|w| w.partial_cmp(&x).unwrap()) {
            Ok(i) => (i + 1).min(cumulative.len() - 1),
            Err(i) => i.min(cumulative.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn below_covers_small_range() {
        let mut r = Rng::new(9);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            seen[r.below(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut base = Rng::new(1234);
        let mut s1 = base.split(1);
        let mut s2 = base.split(2);
        let same = (0..64).filter(|_| s1.next_u64() == s2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn weighted_prefers_heavy_bucket() {
        let mut r = Rng::new(99);
        let cum = vec![0.1, 0.2, 1.0]; // bucket 2 has 80% mass
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&cum)] += 1;
        }
        assert!(counts[2] > 7000, "{counts:?}");
    }
}
