//! Wall-clock measurement helpers shared by the bench harness and the
//! coordinator's per-job accounting.

use std::time::{Duration, Instant};

/// A simple start/stop timer.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Measure `f`, returning (result, elapsed).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed())
}

/// Aggregate of repeated measurements (the bench harness reports min —
/// least noisy on a shared host — plus mean for context).
#[derive(Debug, Clone, Default)]
pub struct Samples {
    pub runs: Vec<Duration>,
}

impl Samples {
    pub fn push(&mut self, d: Duration) {
        self.runs.push(d);
    }

    pub fn min_ms(&self) -> f64 {
        self.runs
            .iter()
            .min()
            .map(|d| d.as_secs_f64() * 1e3)
            .unwrap_or(f64::NAN)
    }

    pub fn mean_ms(&self) -> f64 {
        if self.runs.is_empty() {
            return f64::NAN;
        }
        let total: f64 = self.runs.iter().map(|d| d.as_secs_f64() * 1e3).sum();
        total / self.runs.len() as f64
    }

    pub fn max_ms(&self) -> f64 {
        self.runs
            .iter()
            .max()
            .map(|d| d.as_secs_f64() * 1e3)
            .unwrap_or(f64::NAN)
    }

    /// Nearest-rank percentile in ms (`p` in 0..=100) — the serving
    /// bench reports p50/p99 batched-update latency.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.runs.is_empty() {
            return f64::NAN;
        }
        let mut v: Vec<f64> = self.runs.iter().map(|d| d.as_secs_f64() * 1e3).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(1));
        assert!(t.elapsed_ms() >= 1.0);
    }

    #[test]
    fn samples_stats() {
        let mut s = Samples::default();
        s.push(Duration::from_millis(2));
        s.push(Duration::from_millis(4));
        assert!((s.min_ms() - 2.0).abs() < 0.5);
        assert!((s.mean_ms() - 3.0).abs() < 0.5);
        assert!((s.max_ms() - 4.0).abs() < 0.5);
    }

    #[test]
    fn empty_samples_are_nan() {
        let s = Samples::default();
        assert!(s.min_ms().is_nan());
        assert!(s.mean_ms().is_nan());
        assert!(s.percentile_ms(50.0).is_nan());
    }

    #[test]
    fn percentiles_bracket_the_distribution() {
        let mut s = Samples::default();
        for ms in [1u64, 2, 3, 4, 5, 6, 7, 8, 9, 100] {
            s.push(Duration::from_millis(ms));
        }
        assert!((s.percentile_ms(0.0) - 1.0).abs() < 0.5);
        assert!((s.percentile_ms(50.0) - 5.0).abs() < 1.5);
        assert!((s.percentile_ms(100.0) - 100.0).abs() < 0.5);
        assert!(s.percentile_ms(99.0) >= s.percentile_ms(50.0));
    }
}
