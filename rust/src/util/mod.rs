//! Shared substrate utilities: deterministic RNG, timing, human-readable
//! formatting, and a small property-testing harness (`quickcheck`-lite,
//! built in-tree because the environment is offline).

pub mod fmt;
pub mod quickcheck;
pub mod rng;
pub mod timer;

/// Number of worker threads to use for the BSP engine.
///
/// Honours `PICO_THREADS` when set (useful for reproducible benches),
/// otherwise the host parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PICO_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }
}
