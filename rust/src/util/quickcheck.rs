//! `quickcheck`-lite: a minimal in-tree property-testing harness.
//!
//! The offline environment carries no `proptest`/`quickcheck` crate, so the
//! test suites use this: seeded generators, a configurable number of cases,
//! and greedy input shrinking for failures. It is deliberately small — the
//! generators the k-core tests need are graphs, integer vectors, and
//! scalars — but the shrinking loop is real, so failing cases come back
//! minimal enough to debug.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 64,
            seed: 0xC0FFEE,
            max_shrink_steps: 400,
        }
    }
}

/// A value generator paired with a shrinker.
pub trait Arbitrary: Sized + Clone + std::fmt::Debug {
    fn generate(rng: &mut Rng, size: usize) -> Self;

    /// Candidate smaller values; empty when fully shrunk.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Arbitrary for u32 {
    fn generate(rng: &mut Rng, size: usize) -> Self {
        rng.below((size.max(1) as u64) * 4) as u32
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Arbitrary for usize {
    fn generate(rng: &mut Rng, size: usize) -> Self {
        rng.below_usize(size.max(1) * 4)
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl<T: Arbitrary> Arbitrary for Vec<T> {
    fn generate(rng: &mut Rng, size: usize) -> Self {
        let len = rng.below_usize(size.max(1) + 1);
        (0..len).map(|_| T::generate(rng, size)).collect()
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Halve, drop one element, shrink one element.
        out.push(self[..self.len() / 2].to_vec());
        if self.len() > 1 {
            let mut v = self.clone();
            v.remove(self.len() - 1);
            out.push(v);
            let mut v = self.clone();
            v.remove(0);
            out.push(v);
        }
        for (i, x) in self.iter().enumerate().take(4) {
            for sx in x.shrink().into_iter().take(2) {
                let mut v = self.clone();
                v[i] = sx;
                out.push(v);
            }
        }
        out
    }
}

/// Pairs.
impl<A: Arbitrary, B: Arbitrary> Arbitrary for (A, B) {
    fn generate(rng: &mut Rng, size: usize) -> Self {
        (A::generate(rng, size), B::generate(rng, size))
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum CheckResult<T> {
    Pass { cases: usize },
    Fail { original: T, shrunk: T, message: String },
}

/// Run `prop` over `cfg.cases` generated inputs; shrink on first failure.
pub fn check<T: Arbitrary>(
    cfg: &Config,
    prop: impl Fn(&T) -> Result<(), String>,
) -> CheckResult<T> {
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        // Grow input size with the case index so early cases are tiny.
        let size = 2 + case * 2;
        let input = T::generate(&mut rng, size);
        if let Err(msg) = prop(&input) {
            let shrunk = shrink_failure(&input, &prop, cfg.max_shrink_steps);
            return CheckResult::Fail {
                original: input,
                shrunk,
                message: msg,
            };
        }
    }
    CheckResult::Pass { cases: cfg.cases }
}

fn shrink_failure<T: Arbitrary>(
    input: &T,
    prop: &impl Fn(&T) -> Result<(), String>,
    max_steps: usize,
) -> T {
    let mut current = input.clone();
    let mut steps = 0;
    'outer: while steps < max_steps {
        for candidate in current.shrink() {
            steps += 1;
            if prop(&candidate).is_err() {
                current = candidate;
                continue 'outer;
            }
            if steps >= max_steps {
                break 'outer;
            }
        }
        break;
    }
    current
}

/// Assert that the property holds; panics with the shrunk counterexample.
pub fn assert_prop<T: Arbitrary>(cfg: &Config, name: &str, prop: impl Fn(&T) -> Result<(), String>) {
    match check(cfg, prop) {
        CheckResult::Pass { .. } => {}
        CheckResult::Fail {
            original,
            shrunk,
            message,
        } => panic!(
            "property '{name}' failed: {message}\n  original: {original:?}\n  shrunk:   {shrunk:?}"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let cfg = Config::default();
        match check::<Vec<u32>>(&cfg, |v| {
            if v.iter().map(|&x| x as u64).sum::<u64>() >= v.iter().map(|&x| x as u64).max().unwrap_or(0) {
                Ok(())
            } else {
                Err("sum < max".into())
            }
        }) {
            CheckResult::Pass { cases } => assert_eq!(cases, cfg.cases),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn failing_property_shrinks() {
        let cfg = Config { cases: 200, ..Config::default() };
        // Fails whenever the vec contains an element >= 10.
        match check::<Vec<u32>>(&cfg, |v| {
            if v.iter().all(|&x| x < 10) {
                Ok(())
            } else {
                Err("elem >= 10".into())
            }
        }) {
            CheckResult::Fail { shrunk, .. } => {
                // Shrinker should get us close to the minimal witness [10].
                assert!(shrunk.len() <= 2, "shrunk too large: {shrunk:?}");
                assert!(shrunk.iter().any(|&x| x >= 10));
            }
            CheckResult::Pass { .. } => panic!("property should have failed"),
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = Config { cases: 50, seed: 7, ..Config::default() };
        let run = || -> Vec<Vec<u32>> {
            let mut rng = Rng::new(cfg.seed);
            (0..cfg.cases).map(|c| Vec::<u32>::generate(&mut rng, 2 + c * 2)).collect()
        };
        assert_eq!(run(), run());
    }
}
