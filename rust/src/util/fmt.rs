//! Human-readable formatting for tables and logs (counts, durations,
//! throughput), matching the style of the paper's tables.

/// `1234567 -> "1,234,567"`.
pub fn commas(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    let bytes = s.as_bytes();
    for (i, b) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*b as char);
    }
    out
}

/// Compact SI-style count: `1.9K`, `85.7M`, `2.05B`.
pub fn si(n: u64) -> String {
    let n = n as f64;
    if n >= 1e9 {
        format!("{:.2}B", n / 1e9)
    } else if n >= 1e6 {
        format!("{:.1}M", n / 1e6)
    } else if n >= 1e3 {
        format!("{:.1}K", n / 1e3)
    } else {
        format!("{n:.0}")
    }
}

/// Milliseconds with sensible precision (paper reports ms).
pub fn ms(v: f64) -> String {
    if v.is_nan() {
        "-".into()
    } else if v >= 100.0 {
        format!("{v:.1}")
    } else if v >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.3}")
    }
}

/// Speedup in the paper's `1.9x` style.
pub fn speedup(v: f64) -> String {
    if v.is_nan() || !v.is_finite() {
        "-".into()
    } else {
        format!("{v:.1}x")
    }
}

/// Edges/second throughput.
pub fn meps(edges: u64, ms: f64) -> String {
    if ms <= 0.0 {
        return "-".into();
    }
    format!("{:.1} ME/s", edges as f64 / 1e3 / ms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commas_groups() {
        assert_eq!(commas(0), "0");
        assert_eq!(commas(999), "999");
        assert_eq!(commas(1000), "1,000");
        assert_eq!(commas(1234567), "1,234,567");
    }

    #[test]
    fn si_scales() {
        assert_eq!(si(950), "950");
        assert_eq!(si(1_901_000), "1.9M");
        assert_eq!(si(2_054_950_000), "2.05B");
    }

    #[test]
    fn ms_precision() {
        assert_eq!(ms(1234.56), "1234.6");
        assert_eq!(ms(12.345), "12.35");
        assert_eq!(ms(0.1234), "0.123");
        assert_eq!(ms(f64::NAN), "-");
    }

    #[test]
    fn speedup_style() {
        assert_eq!(speedup(1.94), "1.9x");
        assert_eq!(speedup(f64::INFINITY), "-");
    }
}
