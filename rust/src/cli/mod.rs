//! Hand-rolled CLI (the environment carries no `clap`): a small flag
//! parser plus the `pico` subcommands.

pub mod args;
pub mod commands;

pub use args::Args;

/// Top-level usage text.
pub const USAGE: &str = "\
pico — all k-core decomposition paradigms (PICO reproduction)

USAGE:
    pico <COMMAND> [OPTIONS]

COMMANDS:
    run       Decompose one dataset with one algorithm
    suite     Run algorithms across the dataset suite (scheduler demo)
    stats     Print Table II-style statistics for the suite
    analyze   Fig. 3-style multi-access analysis of a dataset
    doctor    Check the XLA runtime and artifacts
    list      List algorithms and suite datasets
    help      Show this message

COMMON OPTIONS:
    --threads N        SPMD worker threads (default: host parallelism)
    --config PATH      Config file (default: ./pico.conf if present)

RUN OPTIONS:
    --algo NAME        Algorithm (see `pico list`); default PO-dyn
    --dataset NAME     Suite dataset name, or a path to .el/.mtx/.pico
    --no-validate      Skip the BZ oracle check
    --metrics          Print instrumented counters

EXAMPLES:
    pico run --algo HistoCore --dataset social-ba --metrics
    pico suite --algos PO-dyn,HistoCore --tier small
    pico stats --tier standard
    pico analyze --dataset social-rmat
";
