//! Hand-rolled CLI (the environment carries no `clap`): a small flag
//! parser plus the `pico` subcommands.

pub mod args;
pub mod commands;

pub use args::Args;

/// Top-level usage text.
pub const USAGE: &str = "\
pico — all k-core decomposition paradigms (PICO reproduction)

USAGE:
    pico <COMMAND> [OPTIONS]

COMMANDS:
    run       Decompose one dataset with one algorithm
    suite     Run algorithms across the dataset suite (alias: bench)
    serve     Host core indices behind the line-protocol TCP server
    cluster   Multi-host topology tooling (`pico cluster status|rebalance`)
    top       Live dashboard over STATS/EVENTS/HEALTH for one host or a cluster
    query     Send protocol commands to a running `pico serve`
    stats     Print Table II-style statistics for the suite
    analyze   Fig. 3-style multi-access analysis of a dataset
    doctor    Check the XLA runtime and artifacts
    list      List algorithms and suite datasets
    help      Show this message

COMMON OPTIONS:
    --threads N        SPMD worker threads (default: host parallelism)
    --config PATH      Config file (default: ./pico.conf if present)

RUN OPTIONS:
    --algo NAME        Algorithm (see `pico list`); default PO-dyn
    --dataset NAME     Suite dataset name, or a path to .el/.mtx/.pico
    --no-validate      Skip the BZ oracle check
    --metrics          Print instrumented counters
    --json             Machine-readable report (also for suite/bench)

SERVE OPTIONS:
    --addr HOST:PORT     Bind address (default 127.0.0.1:7571)
    --dataset NAME       Initial hosted graph (default g1)
    --shards N           Partition the hosted graph across N shards (default 1)
    --partition S        Partition strategy: hash | range (default hash)
    --workers N          Transport worker threads multiplexing the
                         connections (default min(cores, 16); net::pool)
    --max-conns N        Hard cap on live connections (default 1024);
                         accept #cap+1 gets one ERR line and a close.
                         Transport counters surface on the METRICS verb.
                         Set PICO_AUTH_TOKEN (or the topology's
                         auth_token) to gate the shard verbs behind an
                         AUTH preamble.
    --cluster CFG        Serve a multi-host cluster from a topology file:
                         shards placed local or shipped to remote `pico
                         serve` hosts, replica groups with epoch-checked
                         read failover and journal-first delta catch-up
                         (full-manifest re-ship as the fallback; see
                         cluster::config docs for the format, incl. the
                         `journal = N` retention key). SIGTERM / ctrl-c
                         drains connections and flushes pending edits
                         before exit.
    --sync-interval MS   Replica-sync daemon probe interval in ms
                         (default 1000, jittered ±25%; 0 disables —
                         replicas then converge only at drain). Cluster
                         mode only: served FLUSH never blocks on
                         replica sync.
    --batch-fraction F   Recompute when a batch exceeds F of |E| (default 0.02,
                         or the PICO_RECOMPUTE_FRACTION env override)
    --batch-min N        Never recompute below N coalesced edits (default 64)
    --sample-interval MS Stats-sampler period: snapshot the metric
                         registry into the in-process time-series ring
                         every MS ms (default 1000; 0 disables — the
                         windowed `STATS <window_s>` verb and burn-rate
                         HEALTH rules then answer n/a)
    --trace-ring N       Per-query trace ring capacity (default 64; the
                         TRACES verb reads it). PICO_SLOW_QUERY_US sets
                         the slow-query threshold feeding
                         pico_slow_queries_total

CLUSTER OPTIONS (pico cluster status):
    --cluster CFG        Topology file; probes every remote endpoint with
                         SHARDINFO and prints per-shard epochs, roles,
                         replica lag (epochs behind the committed head),
                         and state bytes (the full re-ship cost a delta
                         catch-up avoids)
    --addr HOST:PORT     The coordinator's serve address: its published
                         EPOCH becomes the authoritative lag baseline.
                         Without it the head is inferred from probed
                         primaries (replicas alone only lower-bound it,
                         e.g. with an all-local-primary topology)
    --metrics            Scrape METRICS PROM from the coordinator
                         (--addr) and every remote endpoint, and print
                         one merged exposition: counters and histogram
                         cells sum across hosts, gauges take the max.
                         Hosts serving a truncated/malformed exposition
                         are flagged per-host and fail the exit code
    --events             Pull the structured event journal (EVENTS) from
                         every endpoint and print one merged,
                         time-ordered tail (--last N, default 20)
    --health             Ask every endpoint for its HEALTH verdict and
                         SLO reasons; exits non-zero unless every host
                         answers ok

CLUSTER OPTIONS (pico cluster rebalance):
    --addr HOST:PORT     The live coordinator to drive (default
                         127.0.0.1:7571); --name GRAPH pins the session
                         when it hosts several graphs. Without further
                         flags, prints the dry-run plan (CLUSTER
                         REBALANCE PLAN): per-shard load signals plus
                         every planned split/merge with its reason
    --apply              Plan and execute in one latched step (CLUSTER
                         REBALANCE APPLY); refused with ERR MIGRATING
                         while another structural change is in flight
    --migrate S=ADDR     Live-migrate shard S's primary to the `pico
                         serve` at ADDR instead: manifest + delta-chain
                         catch-up while writes keep flowing, then an
                         epoch-verified fenced cutover

TOP OPTIONS (pico top):
    --cluster CFG        Poll every endpoint of a topology (with --addr
                         for the coordinator); without either flag the
                         default serve address is polled
    --interval MS        Refresh period (default 2000)
    --window S           STATS window for rates/quantiles (default 60)
    --iterations N       Render N frames then exit (default 0 = run
                         until ctrl-c); handy for scripted captures

QUERY OPTIONS:
    --addr HOST:PORT     Server address (default 127.0.0.1:7571)
    --cmd 'A; B; C'      Protocol commands, `;`-separated (see service::server
                         docs: CORENESS, MEMBERS, HISTO, DENSEST, INSERT,
                         DELETE, FLUSH, EPOCH, STATS [window_s [JSON]],
                         METRICS [PROM|JSON], TRACES [n],
                         EVENTS [n [severity]], HEALTH [graph], OPEN, USE,
                         GRAPHS, SHARDS). A coordinator's REDIRECT reply
                         to a shard-local probe (e.g. SHARDCORE) is
                         followed one hop to the owning shard host;
                         PICO_AUTH_TOKEN is sent as the AUTH preamble
                         when set.
    --binary             Upgrade to the length-prefixed binary protocol
                         (unlocks SNAPSHOT / RESTORE)
    --snapshot-file P    Where SNAPSHOT payloads are written and RESTORE
                         payloads are read from (with --binary)

EXAMPLES:
    pico run --algo HistoCore --dataset social-ba --metrics
    pico run --algo PO-dyn --dataset g1 --json
    pico suite --algos PO-dyn,HistoCore --tier small
    pico serve --dataset social-ba --addr 127.0.0.1:7571 --shards 4
    pico serve --cluster cluster.toml
    pico cluster status --cluster cluster.toml
    pico cluster status --cluster cluster.toml --addr 127.0.0.1:7571 --metrics
    pico cluster status --cluster cluster.toml --health
    pico cluster rebalance --addr 127.0.0.1:7571
    pico cluster rebalance --addr 127.0.0.1:7571 --apply
    pico cluster rebalance --addr 127.0.0.1:7571 --migrate 2=10.0.0.9:7571
    pico top --cluster cluster.toml --interval 1000 --window 30
    pico query --cmd 'INSERT 3 9; FLUSH; CORENESS 3; DENSEST; SHARDS'
    pico query --binary --cmd 'SNAPSHOT' --snapshot-file /tmp/social.snap
    pico query --binary --cmd 'RESTORE replica' --snapshot-file /tmp/social.snap
    pico stats --tier standard
    pico analyze --dataset social-rmat
";
