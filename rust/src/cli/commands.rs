//! `pico` subcommand implementations.

use super::args::Args;
use crate::bench::suite::{self, Tier};
use crate::config::Config;
use crate::coordinator::{
    algorithm_names, report, DatasetSpec, Job, Scheduler, SchedulerConfig,
};
use crate::coordinator::report::Table;
use crate::core::bz::bz_coreness;
use crate::graph::{CsrGraph, GraphStats};
use crate::util::fmt;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

fn tier_by_name(name: &str) -> Result<Tier> {
    Ok(match name {
        "small" => Tier::Small,
        "standard" => Tier::Standard,
        "large" => Tier::Large,
        "xla" => Tier::Xla,
        other => bail!("unknown tier '{other}' (small|standard|large|xla)"),
    })
}

/// Resolve `--dataset`: suite name first, then filesystem path.
fn resolve_dataset(name: &str) -> Result<DatasetSpec> {
    if let Some(entry) = suite::by_name(name) {
        return Ok(DatasetSpec::Lazy {
            name: entry.name.to_string(),
            build: Arc::new(|| entry.build()),
        });
    }
    let path = std::path::Path::new(name);
    if path.exists() {
        return Ok(DatasetSpec::Path(path.to_path_buf()));
    }
    bail!("'{name}' is neither a suite dataset (see `pico list`) nor a file")
}

/// `pico run`
pub fn cmd_run(args: &Args, cfg: &Config) -> Result<()> {
    let algo = args.get_or("algo", "PO-dyn").to_string();
    let dataset = resolve_dataset(args.get_or("dataset", "g1"))?;
    let threads = args.parse_num::<usize>("threads")?.unwrap_or(cfg.threads);
    let job = Job::new(dataset, algo)
        .with_threads(threads)
        .with_metrics(args.has("metrics"))
        .with_validation(!args.has("no-validate"));
    let scheduler = Scheduler::new(SchedulerConfig {
        memory_budget: cfg.memory_budget,
        ..Default::default()
    });
    let r = scheduler.run_one(&job);
    print!("{}", report::render_results(std::slice::from_ref(&r)));
    if job.metrics {
        println!(
            "atomics: sub={} add={} cas_retries={} | edge_accesses={} | hindex_evals={} | frontier_pushes={}",
            fmt::commas(r.metrics.atomic_subs),
            fmt::commas(r.metrics.atomic_adds),
            fmt::commas(r.metrics.cas_retries),
            fmt::commas(r.metrics.edge_accesses),
            fmt::commas(r.metrics.hindex_evals),
            fmt::commas(r.metrics.frontier_pushes),
        );
    }
    if !r.ok() {
        bail!("job did not complete cleanly: {:?}", r.outcome);
    }
    Ok(())
}

/// `pico suite`
pub fn cmd_suite(args: &Args, cfg: &Config) -> Result<()> {
    let tier = tier_by_name(args.get_or("tier", &cfg.suite_tier))?;
    let algos: Vec<String> = args
        .get_or("algos", "PO-dyn,HistoCore")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let threads = args.parse_num::<usize>("threads")?.unwrap_or(cfg.threads);
    let mut jobs = Vec::new();
    for entry in suite::suite(tier) {
        for algo in &algos {
            jobs.push(
                Job::new(
                    DatasetSpec::Lazy {
                        name: entry.name.to_string(),
                        build: Arc::new(|| entry.build()),
                    },
                    algo.clone(),
                )
                .with_threads(threads)
                .with_validation(!args.has("no-validate")),
            );
        }
    }
    let scheduler = Scheduler::new(SchedulerConfig {
        memory_budget: cfg.memory_budget,
        ..Default::default()
    });
    let results = scheduler.run(jobs);
    print!("{}", report::render_results(&results));
    let failed = results.iter().filter(|r| !r.ok()).count();
    if failed > 0 {
        bail!("{failed} job(s) failed");
    }
    Ok(())
}

/// `pico stats` — Table II analog.
pub fn cmd_stats(args: &Args, cfg: &Config) -> Result<()> {
    let tier = tier_by_name(args.get_or("tier", &cfg.suite_tier))?;
    let mut t = Table::new(&["dataset", "|V|", "|E|", "d_avg", "std", "d_max", "k_max", "category"]);
    for entry in suite::suite(tier) {
        let g = entry.build();
        let core = bz_coreness(&g);
        let s = GraphStats::measure(&g).with_kmax(&core);
        t.row(vec![
            entry.name.to_string(),
            fmt::si(s.vertices),
            fmt::si(s.edges),
            format!("{:.2}", s.d_avg),
            format!("{:.1}", s.d_std),
            s.d_max.to_string(),
            s.k_max.unwrap_or(0).to_string(),
            entry.category.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

/// `pico analyze` — Fig. 3 analog.
pub fn cmd_analyze(args: &Args, _cfg: &Config) -> Result<()> {
    let spec = resolve_dataset(args.get_or("dataset", "social-rmat"))?;
    let g: Arc<CsrGraph> = spec.load()?;
    let p = crate::analysis::activation_profile(&g);
    println!("dataset {} — h-index iterations: {}", g.name, p.iterations);
    println!(
        "wasted reactivations (estimate unchanged next iter): {:.1}%",
        p.wasted_reactivation_ratio * 100.0
    );
    let mut t = Table::new(&["threshold t", "% vertices changed > t", "% edges swept > t"]);
    for thr in [0u32, 1, 2, 5, 10] {
        t.row(vec![
            thr.to_string(),
            format!("{:.1}%", p.vertices_changed_more_than(thr) * 100.0),
            format!("{:.1}%", p.edges_accessed_more_than(&g, thr) * 100.0),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

/// `pico doctor`
pub fn cmd_doctor(_args: &Args, _cfg: &Config) -> Result<()> {
    println!("host threads: {}", crate::util::default_threads());
    let store = crate::runtime::ArtifactStore::open_default()
        .context("artifacts not found — run `make artifacts`")?;
    println!("artifacts: {} buckets {:?}", store.buckets().len(), store.buckets());
    let worker = crate::runtime::XlaWorker::spawn(store)?;
    println!("pjrt: {}", worker.platform()?);
    let r = worker.decompose(crate::runtime::artifacts::Kind::Peel, &crate::graph::examples::g1())?;
    anyhow::ensure!(
        r.core == crate::graph::examples::g1_coreness(),
        "XLA smoke test produced wrong coreness"
    );
    println!("xla smoke test (G1 via VecPeel): ok");
    Ok(())
}

/// `pico list`
pub fn cmd_list(_args: &Args, _cfg: &Config) -> Result<()> {
    println!("algorithms:");
    for a in algorithm_names() {
        println!("  {a}");
    }
    println!("\nsuite datasets (name [tier] category):");
    for e in suite::all_entries() {
        println!("  {} [{:?}] {}", e.name, e.tier, e.category);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_names() {
        assert!(tier_by_name("small").is_ok());
        assert!(tier_by_name("weird").is_err());
    }

    #[test]
    fn dataset_resolution() {
        assert!(resolve_dataset("g1").is_ok());
        assert!(resolve_dataset("definitely-not-a-dataset").is_err());
    }

    #[test]
    fn run_command_smoke() {
        let args = Args::parse(
            &["run".into(), "--algo".into(), "PeelOne".into(), "--dataset".into(), "g1".into()],
            &["metrics", "no-validate"],
        )
        .unwrap();
        cmd_run(&args, &Config::default()).unwrap();
    }

    #[test]
    fn list_command_smoke() {
        cmd_list(&Args::default(), &Config::default()).unwrap();
    }
}
