//! `pico` subcommand implementations.

use super::args::Args;
use crate::bench::suite::{self, Tier};
use crate::config::Config;
use crate::coordinator::{
    algorithm_names, report, DatasetSpec, Job, Scheduler, SchedulerConfig,
};
use crate::coordinator::report::Table;
use crate::core::bz::bz_coreness;
use crate::graph::{CsrGraph, GraphStats};
use crate::util::fmt;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

fn tier_by_name(name: &str) -> Result<Tier> {
    Ok(match name {
        "small" => Tier::Small,
        "standard" => Tier::Standard,
        "large" => Tier::Large,
        "xla" => Tier::Xla,
        other => bail!("unknown tier '{other}' (small|standard|large|xla)"),
    })
}

/// Resolve `--dataset`: suite name first, then filesystem path
/// (shared with the serve protocol via [`DatasetSpec::resolve`]).
fn resolve_dataset(name: &str) -> Result<DatasetSpec> {
    DatasetSpec::resolve(name)
}

/// `pico run`
pub fn cmd_run(args: &Args, cfg: &Config) -> Result<()> {
    let algo = args.get_or("algo", "PO-dyn").to_string();
    let dataset = resolve_dataset(args.get_or("dataset", "g1"))?;
    let threads = args.parse_num::<usize>("threads")?.unwrap_or(cfg.threads);
    let job = Job::new(dataset, algo)
        .with_threads(threads)
        .with_metrics(args.has("metrics"))
        .with_validation(!args.has("no-validate"));
    let scheduler = Scheduler::new(SchedulerConfig {
        memory_budget: cfg.memory_budget,
        ..Default::default()
    });
    let r = scheduler.run_one(&job);
    if args.has("json") {
        print!("{}", report::render_results_json(std::slice::from_ref(&r)));
        if !r.ok() {
            bail!("job did not complete cleanly: {:?}", r.outcome);
        }
        return Ok(());
    }
    print!("{}", report::render_results(std::slice::from_ref(&r)));
    if job.metrics {
        println!(
            "atomics: sub={} add={} cas_retries={} | edge_accesses={} | hindex_evals={} | frontier_pushes={}",
            fmt::commas(r.metrics.atomic_subs),
            fmt::commas(r.metrics.atomic_adds),
            fmt::commas(r.metrics.cas_retries),
            fmt::commas(r.metrics.edge_accesses),
            fmt::commas(r.metrics.hindex_evals),
            fmt::commas(r.metrics.frontier_pushes),
        );
    }
    if !r.ok() {
        bail!("job did not complete cleanly: {:?}", r.outcome);
    }
    Ok(())
}

/// `pico suite`
pub fn cmd_suite(args: &Args, cfg: &Config) -> Result<()> {
    let tier = tier_by_name(args.get_or("tier", &cfg.suite_tier))?;
    let algos: Vec<String> = args
        .get_or("algos", "PO-dyn,HistoCore")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let threads = args.parse_num::<usize>("threads")?.unwrap_or(cfg.threads);
    let mut jobs = Vec::new();
    for entry in suite::suite(tier) {
        for algo in &algos {
            jobs.push(
                Job::new(
                    DatasetSpec::Lazy {
                        name: entry.name.to_string(),
                        build: Arc::new(move || entry.build()),
                    },
                    algo.clone(),
                )
                .with_threads(threads)
                .with_validation(!args.has("no-validate")),
            );
        }
    }
    let scheduler = Scheduler::new(SchedulerConfig {
        memory_budget: cfg.memory_budget,
        ..Default::default()
    });
    let results = scheduler.run(jobs);
    if args.has("json") {
        print!("{}", report::render_results_json(&results));
    } else {
        print!("{}", report::render_results(&results));
    }
    let failed = results.iter().filter(|r| !r.ok()).count();
    if failed > 0 {
        bail!("{failed} job(s) failed");
    }
    Ok(())
}

/// `pico stats` — Table II analog.
pub fn cmd_stats(args: &Args, cfg: &Config) -> Result<()> {
    let tier = tier_by_name(args.get_or("tier", &cfg.suite_tier))?;
    let mut t = Table::new(&["dataset", "|V|", "|E|", "d_avg", "std", "d_max", "k_max", "category"]);
    for entry in suite::suite(tier) {
        let g = entry.build();
        let core = bz_coreness(&g);
        let s = GraphStats::measure(&g).with_kmax(&core);
        t.row(vec![
            entry.name.to_string(),
            fmt::si(s.vertices),
            fmt::si(s.edges),
            format!("{:.2}", s.d_avg),
            format!("{:.1}", s.d_std),
            s.d_max.to_string(),
            s.k_max.unwrap_or(0).to_string(),
            entry.category.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

/// `pico analyze` — Fig. 3 analog.
pub fn cmd_analyze(args: &Args, _cfg: &Config) -> Result<()> {
    let spec = resolve_dataset(args.get_or("dataset", "social-rmat"))?;
    let g: Arc<CsrGraph> = spec.load()?;
    let p = crate::analysis::activation_profile(&g);
    println!("dataset {} — h-index iterations: {}", g.name, p.iterations);
    println!(
        "wasted reactivations (estimate unchanged next iter): {:.1}%",
        p.wasted_reactivation_ratio * 100.0
    );
    let mut t = Table::new(&["threshold t", "% vertices changed > t", "% edges swept > t"]);
    for thr in [0u32, 1, 2, 5, 10] {
        t.row(vec![
            thr.to_string(),
            format!("{:.1}%", p.vertices_changed_more_than(thr) * 100.0),
            format!("{:.1}%", p.edges_accessed_more_than(&g, thr) * 100.0),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

/// `pico doctor`
pub fn cmd_doctor(_args: &Args, _cfg: &Config) -> Result<()> {
    println!("host threads: {}", crate::util::default_threads());
    match crate::runtime::ArtifactStore::open_default() {
        Ok(store) => {
            println!("artifacts: {} buckets {:?}", store.buckets().len(), store.buckets());
            doctor_xla(store)?;
        }
        Err(e) => println!("artifacts: not found ({e:#}); XLA path unavailable"),
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn doctor_xla(store: crate::runtime::ArtifactStore) -> Result<()> {
    let worker = crate::runtime::XlaWorker::spawn(store)?;
    println!("pjrt: {}", worker.platform()?);
    let r = worker.decompose(crate::runtime::artifacts::Kind::Peel, &crate::graph::examples::g1())?;
    anyhow::ensure!(
        r.core == crate::graph::examples::g1_coreness(),
        "XLA smoke test produced wrong coreness"
    );
    println!("xla smoke test (G1 via VecPeel): ok");
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn doctor_xla(_store: crate::runtime::ArtifactStore) -> Result<()> {
    println!("xla backend: disabled at build time (rebuild with `--features xla`)");
    Ok(())
}

/// Process-wide shutdown request flag, set from SIGINT/SIGTERM. libc is
/// already linked by std, so the handler is installed through a direct
/// `signal(2)` declaration — no new dependency.
#[cfg(unix)]
mod shutdown {
    use std::sync::atomic::{AtomicBool, Ordering};

    static REQUESTED: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_signal(_sig: i32) {
        // async-signal-safe: a single atomic store
        REQUESTED.store(true, Ordering::SeqCst);
    }

    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }

    pub fn install() {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGINT, on_signal as usize);
            signal(SIGTERM, on_signal as usize);
        }
    }

    pub fn requested() -> bool {
        REQUESTED.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod shutdown {
    /// No signal story off unix: `pico serve` runs until killed.
    pub fn install() {}

    pub fn requested() -> bool {
        false
    }
}

/// `pico serve` — host core indices (single, sharded, or a whole
/// cluster via `--cluster <cfg>`) behind the bounded `net` transport
/// (see `service::server` docs for the line + binary protocols, and
/// `net::pool` for `--workers` / `--max-conns`). The shard verbs are
/// gated behind `AUTH` when `PICO_AUTH_TOKEN` (or the topology's
/// `auth_token`) is set. SIGTERM or ctrl-c drains connections and
/// flushes pending edits before exiting.
pub fn cmd_serve(args: &Args, cfg: &Config) -> Result<()> {
    use crate::net::{default_workers, NetConfig};
    use crate::service::{serve_with, BatchConfig, CoreService};
    use crate::shard::PartitionStrategy;

    let addr = args.get_or("addr", "127.0.0.1:7571").to_string();
    let threads = args.parse_num::<usize>("threads")?.unwrap_or(cfg.threads);
    let shards = args.parse_num::<usize>("shards")?.unwrap_or(1);
    if shards == 0 || shards > crate::service::server::MAX_SHARDS {
        bail!(
            "--shards must be 1..={} (got {shards})",
            crate::service::server::MAX_SHARDS
        );
    }
    let strategy = PartitionStrategy::parse(args.get_or("partition", "hash"))?;
    let sync_interval_ms = args.parse_num::<u64>("sync-interval")?.unwrap_or(1000);
    let sample_interval_ms = args.parse_num::<u64>("sample-interval")?.unwrap_or(1000);
    if let Some(n) = args.parse_num::<usize>("trace-ring")? {
        if n == 0 {
            bail!("--trace-ring must be at least 1");
        }
        crate::obs::trace::set_trace_ring_cap(n);
    }
    let max_connections = match args.parse_num::<usize>("max-conns")? {
        Some(0) => bail!("--max-conns must be at least 1"),
        Some(cap) => cap,
        None => NetConfig::default().max_connections,
    };
    // a bare `pico serve` reads the env token; --cluster mode below may
    // supply the topology's token as the fallback
    let env_token = crate::net::env_auth_token();
    let mut net = NetConfig {
        workers: args.parse_num::<usize>("workers")?.unwrap_or(0),
        max_connections,
        conn: crate::net::ConnConfig {
            auth_token: env_token,
            ..Default::default()
        },
    };
    let batch = BatchConfig {
        recompute_fraction: args
            .parse_num::<f64>("batch-fraction")?
            .unwrap_or(BatchConfig::default().recompute_fraction),
        min_recompute_edits: args
            .parse_num::<usize>("batch-min")?
            .unwrap_or(BatchConfig::default().min_recompute_edits),
        threads,
    };

    let service = std::sync::Arc::new(CoreService::new(batch.clone()));
    let mut sync_daemon: Option<crate::service::ReplicaSyncDaemon> = None;
    let (name, s) = if let Some(path) = args.get("cluster") {
        // cluster mode: topology comes from the config file; --dataset
        // overrides its dataset for quick experiments. Shard placement
        // flags would be silently ignored — reject them instead.
        if args.get("shards").is_some() || args.get("partition").is_some() {
            bail!("--shards/--partition come from the topology file in --cluster mode");
        }
        let topo = crate::cluster::ClusterConfig::load(path)?;
        // the coordinator both dials shard hosts with the token (inside
        // ClusterIndex::build) and gates its own shard verbs on it
        if net.conn.auth_token.is_none() {
            net.conn.auth_token = topo.effective_auth_token();
        }
        let dataset = args.get("dataset").unwrap_or(&topo.dataset).to_string();
        let spec = resolve_dataset(&dataset)?;
        let g = spec.load()?;
        let idx = std::sync::Arc::new(crate::cluster::ClusterIndex::build(&g, &topo, batch.clone())?);
        for gs in idx.status() {
            let state = match &gs.primary {
                Ok(st) => format!("up (cluster epoch {})", st.cluster_epoch),
                Err(e) => format!("DOWN: {e}"),
            };
            println!(
                "shard {}: {} primary {} — {}, {} replica(s)",
                gs.shard,
                gs.kind,
                gs.primary_addr,
                state,
                gs.replicas.len()
            );
        }
        let name = topo.name.clone();
        let snap = idx.snapshot();
        service.open_cluster(&name, idx.clone());
        // replica convergence runs off the flush path: a jittered
        // background daemon ships delta chains (full manifests as the
        // fallback) to lagging replicas
        if sync_interval_ms > 0 {
            let interval = std::time::Duration::from_millis(sync_interval_ms);
            sync_daemon = Some(crate::service::ReplicaSyncDaemon::spawn(idx, interval));
            println!("replica-sync daemon: probing every ~{sync_interval_ms}ms (jittered)");
        } else {
            println!("replica-sync daemon: disabled (--sync-interval 0); sync only at drain");
        }
        (name, snap)
    } else {
        if args.get("sync-interval").is_some() {
            bail!("--sync-interval only applies to --cluster mode (replica sync)");
        }
        let dataset_name = args.get_or("dataset", "g1").to_string();
        let spec = resolve_dataset(&dataset_name)?;
        let g = spec.load()?;
        let snap = if shards > 1 {
            let idx = service.open_sharded(&spec.name(), &g, shards, strategy);
            println!(
                "partition: {} shards [{}], {} boundary edges",
                idx.num_shards(),
                idx.strategy().name(),
                idx.boundary_edges()
            );
            idx.snapshot()
        } else {
            service.open(&spec.name(), &g).snapshot()
        };
        (spec.name(), snap)
    };
    let authed = net.conn.auth_token.is_some();
    let workers = if net.workers == 0 {
        default_workers()
    } else {
        net.workers
    };
    let max_conns = net.max_connections;
    let handle = serve_with(service.clone(), &addr, net)?;
    println!(
        "serving '{}' on {} — |V|={} |E|={} k_max={} (epoch {})",
        name,
        handle.addr(),
        s.num_vertices(),
        s.num_edges,
        s.k_max,
        s.epoch
    );
    println!(
        "transport: {workers} workers, {max_conns} connection cap, shard verbs {}",
        if authed { "AUTH-gated" } else { "open (set PICO_AUTH_TOKEN to gate)" }
    );
    println!(
        "batch policy: recompute above max({}, {:.1}% of |E|) coalesced edits",
        batch.min_recompute_edits,
        batch.recompute_fraction * 100.0
    );
    println!("try: pico query --addr {} --cmd 'CORENESS 0'", handle.addr());
    // the sampler snapshots the metric registry into the bounded
    // time-series ring, which is what the windowed `STATS <window_s>`
    // verb and the burn-rate HEALTH rules read from
    let sampler = if sample_interval_ms > 0 {
        let s = crate::obs::Sampler::spawn(std::time::Duration::from_millis(sample_interval_ms));
        println!(
            "stats sampler: every {sample_interval_ms}ms (STATS <window_s> / HEALTH; trace ring {} entries)",
            crate::obs::trace::trace_ring_cap()
        );
        Some(s)
    } else {
        println!(
            "stats sampler: disabled (--sample-interval 0); windowed STATS and burn-rate HEALTH answer n/a"
        );
        None
    };

    // run until SIGTERM/ctrl-c, then drain instead of dropping
    // connections mid-frame
    shutdown::install();
    while !shutdown::requested() {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    println!("shutdown requested — draining connections...");
    // stop the sync daemon first: flush_all below runs one final
    // deterministic sync, and two concurrent passes would double-ship
    drop(sync_daemon);
    let drained = handle.drain(std::time::Duration::from_secs(5));
    for (graph, outcome) in service.flush_all() {
        match outcome {
            Ok((epoch, applied)) => {
                println!("flushed {applied} pending edit(s) on '{graph}' -> epoch {epoch}")
            }
            Err(e) => println!("WARNING: pending edits on '{graph}' could not be flushed: {e}"),
        }
    }
    // the sampler outlives the drain so the final flush still lands in
    // the ring; dropping it stops and joins the thread
    drop(sampler);
    if drained {
        println!("drained cleanly; bye");
    } else {
        // a client stalled mid-request pins its handler until the
        // process exits — be honest about what happens to it
        println!("drain timed out; exiting with connections still open (process exit closes them)");
    }
    Ok(())
}

/// `pico cluster <subcommand>` — topology tooling. `status` probes every
/// endpoint of a `--cluster` config over the protocol; with `--metrics`
/// it scrapes `METRICS PROM` from every host instead and prints one
/// merged cluster-wide exposition. `rebalance` drives the elastic
/// resharding control plane on a live coordinator.
pub fn cmd_cluster(args: &Args, _cfg: &Config) -> Result<()> {
    match args.subcommand.as_str() {
        "status" => cluster_status(args),
        "rebalance" => cluster_rebalance(args),
        "" => bail!("usage: pico cluster status|rebalance ..."),
        other => bail!("unknown cluster subcommand '{other}' (have: status rebalance)"),
    }
}

/// `pico cluster rebalance --addr <coordinator>` — a thin client for the
/// `CLUSTER REBALANCE` namespace. The default is a dry run (`CLUSTER
/// REBALANCE PLAN`: the load snapshot plus every planned move with its
/// reason); `--apply` plans and executes in one latched step; `--migrate
/// <shard>=<host:port>` live-migrates one shard's primary instead.
/// `--name <graph>` pins the session when the coordinator hosts several
/// graphs; `PICO_AUTH_TOKEN` is sent as the `AUTH` preamble when set.
/// After the action, the coordinator's move history (`CLUSTER MOVES`)
/// is printed so the operator sees what the cluster has done so far.
fn cluster_rebalance(args: &Args) -> Result<()> {
    use crate::net::client::Client;

    // validate before dialing: a malformed --migrate spec must not cost
    // a connection attempt
    let migrate = match args.get("migrate") {
        Some(spec) => Some(spec.split_once('=').ok_or_else(|| {
            anyhow::anyhow!("--migrate wants <shard>=<host:port>, got '{spec}'")
        })?),
        None => None,
    };
    let addr = args.get_or("addr", "127.0.0.1:7571");
    let mut client = Client::connect(addr)
        .with_context(|| format!("connecting to the coordinator at {addr}"))?;
    if let Some(token) = crate::net::env_auth_token() {
        client.auth(&token)?;
    }
    if let Some(name) = args.get("name") {
        client
            .use_graph(name)
            .with_context(|| format!("selecting '{name}' on the coordinator"))?;
    }
    if let Some((shard, target)) = migrate {
        let reply = client.send_line(&format!("CLUSTER REBALANCE MIGRATE {shard} {target}"))?;
        println!("{reply}");
        if reply.starts_with("ERR") {
            bail!("migration rejected: {reply}");
        }
    } else {
        let cmd = if args.has("apply") {
            "CLUSTER REBALANCE APPLY"
        } else {
            "CLUSTER REBALANCE PLAN"
        };
        let (head, lines) = client.send_multiline(cmd)?;
        println!("{head}");
        for l in &lines {
            println!("  {l}");
        }
    }
    let (head, lines) = client.send_multiline("CLUSTER MOVES")?;
    println!("{head}");
    for l in &lines {
        println!("  {l}");
    }
    client.quit();
    Ok(())
}

fn cluster_status(args: &Args) -> Result<()> {
    use crate::cluster::{ClusterConfig, Endpoint, RemoteShard};
    use crate::shard::backend::{ShardStatus, NEVER_COMMITTED};

    let path = args
        .get("cluster")
        .ok_or_else(|| anyhow::anyhow!("--cluster <cfg> is required"))?;
    let topo = ClusterConfig::load(path)?;
    if args.has("metrics") {
        return cluster_metrics(args, &topo);
    }
    if args.has("events") {
        return cluster_events(args, &topo);
    }
    if args.has("health") {
        return cluster_health(args, &topo);
    }
    println!(
        "cluster '{}' — dataset {}, {} shards [{}], journal {} epoch(s)",
        topo.name,
        topo.dataset,
        topo.num_shards(),
        topo.partition.name(),
        topo.journal_epochs
    );
    // Probe everything first: replica lag is relative to the committed
    // head. The authoritative head is the coordinator's published epoch
    // (probe it with --addr); without that, fall back to the newest
    // cluster epoch among probed *primaries* (they commit every epoch),
    // then among all probes — replicas alone can only give a lower
    // bound, so an all-local-primary topology with one lagging replica
    // would otherwise report lag 0.
    struct Probe {
        shard: usize,
        role: &'static str,
        endpoint: String,
        status: Option<Option<ShardStatus>>, // None = local primary (unprobed)
    }
    let mut probes = Vec::new();
    for (i, spec) in topo.shards.iter().enumerate() {
        let graph = topo.shard_graph(i);
        let probe = |role: &'static str, addr: &str| Probe {
            shard: i,
            role,
            endpoint: addr.to_string(),
            status: Some(RemoteShard::new(i, addr, &graph).status().ok()),
        };
        match &spec.primary {
            Endpoint::Local => probes.push(Probe {
                shard: i,
                role: "primary",
                endpoint: "local".into(),
                status: None,
            }),
            Endpoint::Remote(addr) => probes.push(probe("primary", addr)),
        }
        for addr in &spec.replicas {
            probes.push(probe("replica", addr));
        }
    }
    let probed_head = |role: &str| {
        probes
            .iter()
            .filter(|p| role.is_empty() || p.role == role)
            .filter_map(|p| p.status.as_ref()?.as_ref())
            .map(|st| st.cluster_epoch)
            .filter(|&e| e != NEVER_COMMITTED)
            .max()
    };
    let head = match args.get("addr") {
        Some(addr) => Some(coordinator_epoch(addr, &topo.name).with_context(|| {
            format!("probing the coordinator at {addr} for the published epoch")
        })?),
        None => probed_head("primary").or_else(|| probed_head("")),
    };
    let mut t = Table::new(&[
        "shard", "role", "endpoint", "state", "epoch", "cluster", "lag", "owned", "kmax",
        "state bytes",
    ]);
    let mut down = 0usize;
    for p in &probes {
        let dash = || "-".to_string();
        let row = match &p.status {
            None => vec![
                "in-coordinator".into(),
                dash(),
                dash(),
                dash(),
                dash(),
                dash(),
                dash(),
            ],
            Some(None) => {
                down += 1;
                vec!["down".into(), dash(), dash(), dash(), dash(), dash(), dash()]
            }
            Some(Some(st)) => {
                // lag in epochs behind the head; `bytes` is the exact
                // full-manifest size — the cost of a snapshot catch-up
                // (a delta chain is cheaper whenever the journal covers
                // the gap)
                let (cluster, lag) = match (head, st.cluster_epoch) {
                    (_, NEVER_COMMITTED) => ("never".to_string(), "full".to_string()),
                    (Some(h), e) if e < h => (e.to_string(), (h - e).to_string()),
                    (_, e) => (e.to_string(), "0".to_string()),
                };
                vec![
                    "up".into(),
                    st.epoch.to_string(),
                    cluster,
                    lag,
                    st.owned.to_string(),
                    st.k_max.to_string(),
                    fmt::si(st.state_bytes),
                ]
            }
        };
        let mut cells = vec![p.shard.to_string(), p.role.to_string(), p.endpoint.clone()];
        cells.extend(row);
        t.row(cells);
    }
    print!("{}", t.render());
    if down > 0 {
        bail!("{down} endpoint(s) down");
    }
    Ok(())
}

/// Every protocol endpoint of a topology: the coordinator (`--addr`)
/// first, then each remote primary and replica. Several shards may
/// share a host, so addresses are deduplicated.
fn topology_endpoints(args: &Args, topo: &crate::cluster::ClusterConfig) -> Vec<String> {
    use crate::cluster::Endpoint;

    let mut endpoints: Vec<String> = Vec::new();
    if let Some(addr) = args.get("addr") {
        endpoints.push(addr.to_string());
    }
    for spec in &topo.shards {
        if let Endpoint::Remote(addr) = &spec.primary {
            endpoints.push(addr.clone());
        }
        endpoints.extend(spec.replicas.iter().cloned());
    }
    let mut seen = std::collections::BTreeSet::new();
    endpoints.retain(|a| seen.insert(a.clone()));
    endpoints
}

/// `pico cluster status --metrics`: scrape `METRICS PROM` from the
/// coordinator (`--addr`) and every remote endpoint of the topology,
/// then print one merged exposition — counters and histogram cells
/// sum across hosts, gauges take the max (see [`crate::obs::expo`]).
/// A host answering a truncated or malformed exposition is flagged
/// per-host and in the exit code; its readable part still merges.
fn cluster_metrics(args: &Args, topo: &crate::cluster::ClusterConfig) -> Result<()> {
    use crate::obs::expo::parse_prom_strict;
    use crate::obs::merge_prom;

    let auth = crate::net::env_auth_token().or_else(|| topo.effective_auth_token());
    let endpoints = topology_endpoints(args, topo);
    if endpoints.is_empty() {
        bail!("nothing to scrape: all-local topology and no --addr for the coordinator");
    }
    let mut texts = Vec::new();
    let mut down = 0usize;
    let mut bad = 0usize;
    for addr in &endpoints {
        match scrape_prom(addr, auth.as_deref()) {
            Ok(text) => {
                // a host serving garbage is as alarming as one not
                // answering; every build emits pico_uptime_seconds, so
                // its absence means the scrape was cut short
                let (parsed, skipped) = parse_prom_strict(&text);
                let no_uptime = !parsed
                    .samples
                    .keys()
                    .any(|s| s.starts_with(crate::obs::names::UPTIME_SECONDS));
                if skipped > 0 || no_uptime {
                    bad += 1;
                    eprintln!(
                        "WARNING: {addr}: partial/malformed exposition ({skipped} unreadable line(s){})",
                        if no_uptime { "; no pico_uptime_seconds" } else { "" }
                    );
                    println!("# scraped {addr} (PARTIAL)");
                } else {
                    println!("# scraped {addr}");
                }
                texts.push(text);
            }
            Err(e) => {
                down += 1;
                eprintln!("WARNING: scraping {addr}: {e:#}");
            }
        }
    }
    if texts.is_empty() {
        bail!("no endpoint could be scraped ({down} down)");
    }
    print!("{}", merge_prom(&texts));
    if down > 0 || bad > 0 {
        bail!("{down} endpoint(s) down, {bad} with partial/malformed expositions");
    }
    Ok(())
}

/// `pico cluster status --events`: pull the structured event journal
/// (`EVENTS <n>`, the `--last` flag) from every endpoint and print one
/// merged, time-ordered tail, each line suffixed with its host.
fn cluster_events(args: &Args, topo: &crate::cluster::ClusterConfig) -> Result<()> {
    let auth = crate::net::env_auth_token().or_else(|| topo.effective_auth_token());
    let endpoints = topology_endpoints(args, topo);
    if endpoints.is_empty() {
        bail!("nothing to poll: all-local topology and no --addr for the coordinator");
    }
    let n = args.parse_num::<usize>("last")?.unwrap_or(20);
    let mut merged: Vec<(u64, String)> = Vec::new();
    let mut down = 0usize;
    for addr in &endpoints {
        match poll_lines(addr, auth.as_deref(), &format!("EVENTS {n}")) {
            Ok(lines) => {
                for line in lines {
                    // rendered events lead with their unix-ms stamp —
                    // that token is the cross-host sort key
                    let t = event_stamp(&line);
                    merged.push((t, format!("{line}  [{addr}]")));
                }
            }
            Err(e) => {
                down += 1;
                eprintln!("WARNING: polling {addr}: {e:#}");
            }
        }
    }
    merged.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
    if merged.is_empty() {
        println!("(no events)");
    }
    for (_, line) in &merged {
        println!("{line}");
    }
    if down > 0 {
        bail!("{down} endpoint(s) down");
    }
    Ok(())
}

/// `pico cluster status --health`: ask every endpoint for its `HEALTH`
/// verdict and print it with the SLO reasons. The exit code is the
/// cluster's: non-zero unless every host answers and answers `ok`.
fn cluster_health(args: &Args, topo: &crate::cluster::ClusterConfig) -> Result<()> {
    use crate::obs::Verdict;

    let auth = crate::net::env_auth_token().or_else(|| topo.effective_auth_token());
    let endpoints = topology_endpoints(args, topo);
    if endpoints.is_empty() {
        bail!("nothing to poll: all-local topology and no --addr for the coordinator");
    }
    let mut worst = Verdict::Ok;
    let mut down = 0usize;
    for addr in &endpoints {
        match poll_health(addr, auth.as_deref()) {
            Ok((verdict, reasons)) => {
                println!("{addr}: {}", verdict.as_str());
                for r in &reasons {
                    println!("  - {r}");
                }
                worst = worst.max(verdict);
            }
            Err(e) => {
                down += 1;
                println!("{addr}: down ({e:#})");
            }
        }
    }
    println!("cluster: {}", if down > 0 { "down" } else { worst.as_str() });
    if down > 0 {
        bail!("{down} endpoint(s) down");
    }
    if worst > Verdict::Ok {
        bail!("cluster health is {}", worst.as_str());
    }
    Ok(())
}

/// The leading unix-ms token of a rendered event line (0 when absent,
/// which sorts malformed lines first instead of dropping them).
fn event_stamp(line: &str) -> u64 {
    line.split_whitespace()
        .next()
        .and_then(|t| t.parse().ok())
        .unwrap_or(0)
}

/// One multiline verb exchange over the line protocol.
fn poll_lines(addr: &str, auth: Option<&str>, cmd: &str) -> Result<Vec<String>> {
    use crate::net::client::Client;

    let mut client = Client::connect(addr)?;
    if let Some(token) = auth {
        client.auth(token)?;
    }
    let (_head, lines) = client.send_multiline(cmd)?;
    client.quit();
    Ok(lines)
}

/// A `HEALTH` exchange: the parsed verdict plus its reason lines.
fn poll_health(addr: &str, auth: Option<&str>) -> Result<(crate::obs::Verdict, Vec<String>)> {
    use crate::net::client::{field, Client};

    let mut client = Client::connect(addr)?;
    if let Some(token) = auth {
        client.auth(token)?;
    }
    let (head, reasons) = client.send_multiline("HEALTH")?;
    client.quit();
    let verdict = crate::obs::Verdict::parse(field(&head, "health")?)
        .ok_or_else(|| anyhow::anyhow!("unparseable HEALTH verdict in '{head}'"))?;
    Ok((verdict, reasons))
}

/// One host's worth of dashboard state for `pico top`.
struct TopSample {
    stats: std::collections::BTreeMap<String, String>,
    verdict: crate::obs::Verdict,
    reasons: Vec<String>,
    events: Vec<String>,
}

/// Poll one host: `STATS <window_s>` (tolerated missing — a host
/// running without a graph context still dashboards), then the
/// transport-level `HEALTH` and `EVENTS`.
fn poll_top(addr: &str, auth: Option<&str>, window_s: u64) -> Result<TopSample> {
    use crate::net::client::{field, Client};

    let mut client = Client::connect(addr)?;
    if let Some(token) = auth {
        client.auth(token)?;
    }
    let stats = match client.send_multiline(&format!("STATS {window_s}")) {
        Ok((_head, lines)) => lines
            .iter()
            .filter_map(|l| l.split_once(' '))
            .map(|(k, v)| (k.to_string(), v.trim().to_string()))
            .collect(),
        Err(_) => std::collections::BTreeMap::new(),
    };
    let (head, reasons) = client.send_multiline("HEALTH")?;
    let (_head, events) = client.send_multiline("EVENTS 5")?;
    client.quit();
    let verdict = crate::obs::Verdict::parse(field(&head, "health")?)
        .ok_or_else(|| anyhow::anyhow!("unparseable HEALTH verdict in '{head}'"))?;
    Ok(TopSample { stats, verdict, reasons, events })
}

/// `pico top` — a live terminal dashboard over the observability verbs:
/// one row per host with windowed rates and quantiles (`STATS`), the
/// `HEALTH` verdict with its SLO reasons, and a merged cross-host tail
/// of recent journal events. Redraws every `--interval` ms until
/// ctrl-c, or for `--iterations N` refreshes when scripting a capture.
/// Hosts come from `--cluster <cfg>` plus `--addr`; with neither, the
/// default serve address is polled.
pub fn cmd_top(args: &Args, _cfg: &Config) -> Result<()> {
    let interval_ms = args.parse_num::<u64>("interval")?.unwrap_or(2000).max(100);
    let window_s = args.parse_num::<u64>("window")?.unwrap_or(60).max(1);
    let iterations = args.parse_num::<u64>("iterations")?.unwrap_or(0);
    let mut auth = crate::net::env_auth_token();
    let mut endpoints: Vec<String> = Vec::new();
    if let Some(path) = args.get("cluster") {
        let topo = crate::cluster::ClusterConfig::load(path)?;
        if auth.is_none() {
            auth = topo.effective_auth_token();
        }
        endpoints = topology_endpoints(args, &topo);
    } else if let Some(addr) = args.get("addr") {
        endpoints.push(addr.to_string());
    }
    if endpoints.is_empty() {
        endpoints.push("127.0.0.1:7571".to_string());
    }

    shutdown::install();
    let mut tick = 0u64;
    loop {
        let mut rows = Table::new(&[
            "host", "health", "qps", "edits/s", "q p99 us", "flush p99 us", "lag", "cutoffs/s",
            "slow/s", "err/s",
        ]);
        let mut events: Vec<(u64, String)> = Vec::new();
        let mut reasons: Vec<String> = Vec::new();
        for addr in &endpoints {
            match poll_top(addr, auth.as_deref(), window_s) {
                Ok(h) => {
                    let pick = |k: &str| h.stats.get(k).cloned().unwrap_or_else(|| "n/a".into());
                    rows.row(vec![
                        addr.clone(),
                        h.verdict.as_str().to_string(),
                        pick("qps"),
                        pick("edits_per_s"),
                        pick("query_p99_us"),
                        pick("flush_total_p99_us"),
                        pick("replica_lag_epochs"),
                        pick("net_cutoffs_per_s"),
                        pick("slow_queries_per_s"),
                        pick("error_events_per_s"),
                    ]);
                    reasons.extend(h.reasons.iter().map(|r| format!("{addr}: {r}")));
                    events.extend(
                        h.events
                            .into_iter()
                            .map(|l| (event_stamp(&l), format!("{l}  [{addr}]"))),
                    );
                }
                Err(_) => {
                    let mut row = vec![addr.clone(), "down".to_string()];
                    row.extend(vec!["-".to_string(); 8]);
                    rows.row(row);
                }
            }
        }
        events.sort_by(|a, b| b.0.cmp(&a.0).then_with(|| a.1.cmp(&b.1)));
        events.truncate(8);
        // one ANSI clear-and-home per refresh: the whole frame redraws
        print!("\x1b[2J\x1b[H");
        println!(
            "pico top — {} host(s), window {window_s}s, refresh {interval_ms}ms (ctrl-c quits)",
            endpoints.len()
        );
        print!("{}", rows.render());
        if !reasons.is_empty() {
            println!("\nhealth reasons:");
            for r in &reasons {
                println!("  - {r}");
            }
        }
        println!("\nrecent events (newest first):");
        if events.is_empty() {
            println!("  (none)");
        }
        for (_, line) in &events {
            println!("  {line}");
        }
        use std::io::Write as _;
        let _ = std::io::stdout().flush();

        tick += 1;
        if iterations > 0 && tick >= iterations {
            return Ok(());
        }
        // sliced sleep so ctrl-c lands within ~50ms of the keypress
        let mut left = interval_ms;
        while left > 0 && !shutdown::requested() {
            let step = left.min(50);
            std::thread::sleep(std::time::Duration::from_millis(step));
            left -= step;
        }
        if shutdown::requested() {
            return Ok(());
        }
    }
}

/// One `METRICS PROM` scrape over the line protocol.
fn scrape_prom(addr: &str, auth: Option<&str>) -> Result<String> {
    use crate::net::client::Client;

    let mut client = Client::connect(addr)?;
    if let Some(token) = auth {
        client.auth(token)?;
    }
    // send_multiline raises ERR heads, so a rejection surfaces here
    let (_head, lines) = client.send_multiline("METRICS PROM")?;
    client.quit();
    Ok(lines.join("\n"))
}

/// The coordinator's published cluster epoch — the authoritative lag
/// baseline for `pico cluster status --addr`. One shared-client session:
/// `USE <cluster name>` then `EPOCH`.
fn coordinator_epoch(addr: &str, name: &str) -> Result<u64> {
    use crate::net::client::{field_u64, Client};

    let mut client = Client::connect(addr)
        .with_context(|| format!("connecting to the coordinator at {addr}"))?;
    client
        .use_graph(name)
        .with_context(|| format!("selecting '{name}' on the coordinator"))?;
    let reply = client.send_line("EPOCH")?;
    if reply.starts_with("ERR") {
        bail!("coordinator rejected 'EPOCH': {reply}");
    }
    let epoch = field_u64(&reply, "epoch")?;
    client.quit();
    Ok(epoch)
}

/// `pico query` — one-shot client over the shared `net` client: send
/// `;`-separated protocol commands, print each reply line. With
/// `--binary` the connection upgrades to the length-prefixed framing,
/// unlocking `SNAPSHOT`/`RESTORE`: `--snapshot-file PATH` is where a
/// `SNAPSHOT` reply payload is written and where a `RESTORE` command's
/// payload is read from. `PICO_AUTH_TOKEN` (when set) is sent as the
/// `AUTH` preamble so gated shard verbs work from the CLI, and a
/// cluster coordinator's `REDIRECT` reply to a shard-local probe is
/// followed for one hop to the owning shard host.
pub fn cmd_query(args: &Args, _cfg: &Config) -> Result<()> {
    use crate::net::client::{follow_redirect, parse_redirect, Client};
    use crate::net::codec::MAX_FRAME_BYTES;

    let addr = args.get_or("addr", "127.0.0.1:7571");
    let Some(script) = args.get("cmd") else {
        bail!("--cmd is required, e.g. --cmd 'INSERT 1 2; FLUSH; CORENESS 1'");
    };
    let snapshot_file = args.get("snapshot-file");
    let auth = crate::net::env_auth_token();
    let mut client = Client::connect(addr)?;
    if let Some(token) = &auth {
        client.auth(token)?;
    }
    if args.has("binary") {
        client.upgrade_binary()?;
    }
    let binary = client.is_binary();
    let mut failed = false;
    for cmd in script.split(';').map(str::trim).filter(|c| !c.is_empty()) {
        let reply = if binary {
            let mut body = cmd.as_bytes().to_vec();
            if cmd.to_ascii_uppercase().starts_with("RESTORE") {
                let Some(path) = snapshot_file else {
                    bail!("RESTORE needs --snapshot-file PATH for its payload");
                };
                body.push(b'\n');
                body.extend_from_slice(&crate::shard::snapshot::read_file(path)?);
                if body.len() > MAX_FRAME_BYTES {
                    bail!(
                        "snapshot payload is {} bytes, above the server frame cap ({MAX_FRAME_BYTES})",
                        body.len()
                    );
                }
            }
            let frame = client
                .call_raw(&body)
                .with_context(|| format!("exchanging '{cmd}' with {addr}"))?;
            let (head, payload) = crate::net::codec::split_frame(&frame);
            let head = String::from_utf8_lossy(head).into_owned();
            if !payload.is_empty() && head.starts_with("OK snapshot") {
                println!("{head}");
                match snapshot_file {
                    Some(path) => {
                        crate::shard::snapshot::write_file(payload, path)?;
                        println!("  ({} snapshot bytes -> {path})", payload.len());
                    }
                    None => println!(
                        "  ({} snapshot bytes discarded; pass --snapshot-file)",
                        payload.len()
                    ),
                }
                continue;
            }
            head
        } else {
            client.send_line(cmd)?
        };
        // cluster-aware probes: the coordinator names the shard host,
        // the client hops there once and prints the real answer
        let reply = match parse_redirect(&reply) {
            Some(rd) => {
                println!("{reply}");
                let hop = follow_redirect(&rd, cmd, auth.as_deref())?;
                format!("{hop}  (via {})", rd.addr)
            }
            None => reply,
        };
        println!("{reply}");
        failed |= reply.starts_with("ERR");
    }
    client.quit();
    if failed {
        bail!("at least one command was rejected");
    }
    Ok(())
}

/// `pico list`
pub fn cmd_list(_args: &Args, _cfg: &Config) -> Result<()> {
    println!("algorithms:");
    for a in algorithm_names() {
        println!("  {a}");
    }
    println!("\nsuite datasets (name [tier] category):");
    for e in suite::all_entries() {
        println!("  {} [{:?}] {}", e.name, e.tier, e.category);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_names() {
        assert!(tier_by_name("small").is_ok());
        assert!(tier_by_name("weird").is_err());
    }

    #[test]
    fn dataset_resolution() {
        assert!(resolve_dataset("g1").is_ok());
        assert!(resolve_dataset("definitely-not-a-dataset").is_err());
    }

    #[test]
    fn run_command_smoke() {
        let args = Args::parse(
            &["run".into(), "--algo".into(), "PeelOne".into(), "--dataset".into(), "g1".into()],
            &["metrics", "no-validate"],
        )
        .unwrap();
        cmd_run(&args, &Config::default()).unwrap();
    }

    #[test]
    fn run_command_json_smoke() {
        let args = Args::parse(
            &[
                "run".into(),
                "--algo".into(),
                "PeelOne".into(),
                "--dataset".into(),
                "g1".into(),
                "--json".into(),
            ],
            &["metrics", "no-validate", "json"],
        )
        .unwrap();
        assert!(args.has("json"));
        cmd_run(&args, &Config::default()).unwrap();
    }

    #[test]
    fn query_without_server_is_structured_error() {
        let args = Args::parse(
            &[
                "query".into(),
                "--addr".into(),
                "127.0.0.1:1".into(), // reserved port: nothing listens
                "--cmd".into(),
                "PING".into(),
            ],
            &[],
        )
        .unwrap();
        let err = cmd_query(&args, &Config::default()).unwrap_err();
        assert!(err.to_string().contains("connecting"), "{err:#}");
    }

    #[test]
    fn list_command_smoke() {
        cmd_list(&Args::default(), &Config::default()).unwrap();
    }

    #[test]
    fn top_one_iteration_survives_a_down_host() {
        let args = Args::parse(
            &[
                "top".into(),
                "--addr".into(),
                "127.0.0.1:1".into(), // reserved port: nothing listens
                "--iterations".into(),
                "1".into(),
                "--interval".into(),
                "100".into(),
            ],
            &[],
        )
        .unwrap();
        // a dead host renders as a `down` row, not an error
        cmd_top(&args, &Config::default()).unwrap();
    }

    #[test]
    fn event_stamp_sorts_rendered_lines() {
        assert_eq!(event_stamp("1754000000123 warn replica_failover graph=- x"), 1754000000123);
        assert_eq!(event_stamp("not-a-stamp"), 0);
    }

    #[test]
    fn cluster_subcommand_errors_are_structured() {
        let no_sub = Args::parse_with_sub(&["cluster".into()], &[], &["cluster"]).unwrap();
        assert!(cmd_cluster(&no_sub, &Config::default())
            .unwrap_err()
            .to_string()
            .contains("usage"));
        let bogus =
            Args::parse_with_sub(&["cluster".into(), "bogus".into()], &[], &["cluster"]).unwrap();
        assert!(cmd_cluster(&bogus, &Config::default())
            .unwrap_err()
            .to_string()
            .contains("unknown cluster subcommand"));
        let no_cfg =
            Args::parse_with_sub(&["cluster".into(), "status".into()], &[], &["cluster"]).unwrap();
        assert!(cmd_cluster(&no_cfg, &Config::default())
            .unwrap_err()
            .to_string()
            .contains("--cluster"));
        // a malformed --migrate spec fails before any connection attempt
        let bad_migrate = Args::parse_with_sub(
            &[
                "cluster".into(),
                "rebalance".into(),
                "--migrate".into(),
                "nonsense".into(),
            ],
            &[],
            &["cluster"],
        )
        .unwrap();
        assert!(cmd_cluster(&bad_migrate, &Config::default())
            .unwrap_err()
            .to_string()
            .contains("--migrate wants <shard>=<host:port>"));
    }
}
