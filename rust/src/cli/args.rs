//! Tiny argument parser: positional command + `--flag[=| ]value` options
//! + boolean switches. Unknown flags are errors (typos should not pass
//! silently).

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    /// Second positional token, consumed only for commands listed in
    /// `sub_commands` (e.g. `pico cluster status`).
    pub subcommand: String,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse raw args (without argv[0]). `switch_names` lists flags that
    /// take no value.
    pub fn parse(raw: &[String], switch_names: &[&str]) -> Result<Args> {
        Self::parse_with_sub(raw, switch_names, &[])
    }

    /// Like [`Self::parse`], but commands named in `sub_commands` accept
    /// one further positional token as their subcommand. Stray
    /// positionals everywhere else stay hard errors.
    pub fn parse_with_sub(
        raw: &[String],
        switch_names: &[&str],
        sub_commands: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        if let Some(cmd) = it.peek() {
            if !cmd.starts_with("--") {
                out.command = it.next().unwrap().clone();
            }
        }
        if sub_commands.contains(&out.command.as_str()) {
            if let Some(tok) = it.peek() {
                if !tok.starts_with("--") {
                    out.subcommand = it.next().unwrap().clone();
                }
            }
        }
        while let Some(tok) = it.next() {
            let Some(flag) = tok.strip_prefix("--") else {
                bail!("unexpected positional argument '{tok}'");
            };
            if let Some((k, v)) = flag.split_once('=') {
                out.options.insert(k.to_string(), v.to_string());
            } else if switch_names.contains(&flag) {
                out.switches.push(flag.to_string());
            } else if let Some(next) = it.peek() {
                if next.starts_with("--") {
                    bail!("flag --{flag} expects a value");
                }
                out.options.insert(flag.to_string(), it.next().unwrap().clone());
            } else {
                bail!("flag --{flag} expects a value");
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn parse_num<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key}: invalid value '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_options_switches() {
        let a = Args::parse(
            &s(&["run", "--algo", "PeelOne", "--metrics", "--threads=4"]),
            &["metrics"],
        )
        .unwrap();
        assert_eq!(a.command, "run");
        assert_eq!(a.get("algo"), Some("PeelOne"));
        assert_eq!(a.get("threads"), Some("4"));
        assert!(a.has("metrics"));
        assert_eq!(a.parse_num::<usize>("threads").unwrap(), Some(4));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&s(&["run", "--algo"]), &[]).is_err());
        assert!(Args::parse(&s(&["run", "--algo", "--x"]), &[]).is_err());
    }

    #[test]
    fn stray_positional_is_error() {
        assert!(Args::parse(&s(&["run", "oops"]), &[]).is_err());
        // ...unless the command is declared to take a subcommand
        let a = Args::parse_with_sub(&s(&["cluster", "status", "--cluster", "c.toml"]), &[], &["cluster"])
            .unwrap();
        assert_eq!(a.command, "cluster");
        assert_eq!(a.subcommand, "status");
        assert_eq!(a.get("cluster"), Some("c.toml"));
        // a third positional is still an error
        assert!(Args::parse_with_sub(&s(&["cluster", "status", "oops"]), &[], &["cluster"]).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(&s(&["run", "--threads", "many"]), &[]).unwrap();
        assert!(a.parse_num::<usize>("threads").is_err());
    }
}
