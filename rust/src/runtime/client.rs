//! PJRT CPU client construction.
//!
//! The `xla` crate's handles are `Rc`-based (`!Send`/`!Sync`), so the
//! client lives *thread-confined* inside the [`crate::runtime::worker`]
//! service thread; this module only knows how to create one and describe
//! it.

use anyhow::{Context, Result};

/// Create a CPU PJRT client (expensive: do it once per worker thread).
pub fn create_cpu_client() -> Result<xla::PjRtClient> {
    xla::PjRtClient::cpu()
        .map_err(|e| anyhow::anyhow!("{e}"))
        .context("creating PJRT CPU client (is libxla_extension.so on the rpath?)")
}

/// Human-readable platform string (for `pico doctor` / logs).
pub fn platform_info(client: &xla::PjRtClient) -> String {
    format!(
        "{} ({} devices)",
        client.platform_name(),
        client.device_count()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creates_and_reports_cpu() {
        let c = create_cpu_client().expect("client");
        assert!(platform_info(&c).to_lowercase().contains("cpu"));
    }
}
