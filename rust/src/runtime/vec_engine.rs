//! The vectorised decomposition engines — the paper's two paradigms
//! expressed as dense XLA step functions (VETGA [20] lineage), driven to
//! convergence through the [`super::worker::XlaWorker`] service thread.
//! This is the end-to-end proof that the three layers compose: Pallas
//! kernel → jax step function → HLO text → PJRT executable → rust driver.

use super::artifacts::Kind;
use super::worker::XlaWorker;
use crate::core::traits::{DecompositionResult, Decomposer, Paradigm};
use crate::graph::CsrGraph;
use anyhow::Result;
use std::sync::{Arc, Mutex};

static DEFAULT_WORKER: Mutex<Option<Arc<XlaWorker>>> = Mutex::new(None);

/// The process-default XLA worker (respects `$PICO_ARTIFACTS`). Success is
/// cached for the process lifetime; failures are *not*, so a long-running
/// process retries after `make artifacts` lands (std `Mutex`, not
/// `once_cell` — the environment carries none).
pub fn default_worker() -> Result<Arc<XlaWorker>> {
    let mut cached = DEFAULT_WORKER.lock().unwrap();
    if let Some(w) = cached.as_ref() {
        return Ok(w.clone());
    }
    let w = Arc::new(XlaWorker::spawn_default()?);
    *cached = Some(w.clone());
    Ok(w)
}

/// Vectorised PeelOne through XLA.
#[derive(Clone)]
pub struct VecPeel {
    worker: Arc<XlaWorker>,
}

impl VecPeel {
    pub fn new(worker: Arc<XlaWorker>) -> Self {
        Self { worker }
    }

    /// Construct against the process-default worker.
    pub fn open_default() -> Result<Self> {
        Ok(Self::new(default_worker()?))
    }

    /// Fallible decomposition (bucket fit and PJRT errors surface here).
    pub fn try_decompose(&self, g: &CsrGraph) -> Result<DecompositionResult> {
        self.worker.decompose(Kind::Peel, g)
    }
}

impl Decomposer for VecPeel {
    fn name(&self) -> &'static str {
        "VecPeel(XLA)"
    }

    fn paradigm(&self) -> Paradigm {
        Paradigm::Vectorized
    }

    fn decompose_with(&self, g: &CsrGraph, _threads: usize, _metrics: bool) -> DecompositionResult {
        self.try_decompose(g)
            .expect("VecPeel: artifacts missing or graph exceeds bucket (use try_decompose)")
    }
}

/// Vectorised Index2core through XLA.
#[derive(Clone)]
pub struct VecHindex {
    worker: Arc<XlaWorker>,
}

impl VecHindex {
    pub fn new(worker: Arc<XlaWorker>) -> Self {
        Self { worker }
    }

    pub fn open_default() -> Result<Self> {
        Ok(Self::new(default_worker()?))
    }

    pub fn try_decompose(&self, g: &CsrGraph) -> Result<DecompositionResult> {
        self.worker.decompose(Kind::Hindex, g)
    }
}

impl Decomposer for VecHindex {
    fn name(&self) -> &'static str {
        "VecHindex(XLA)"
    }

    fn paradigm(&self) -> Paradigm {
        Paradigm::Vectorized
    }

    fn decompose_with(&self, g: &CsrGraph, _threads: usize, _metrics: bool) -> DecompositionResult {
        self.try_decompose(g)
            .expect("VecHindex: artifacts missing or graph exceeds bucket (use try_decompose)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::{examples, gen};

    /// Artifacts need the JAX/XLA toolchain; skip (not fail) when absent.
    fn skip_without_artifacts(test: &str) -> bool {
        if default_worker().is_err() {
            eprintln!("SKIP {test}: XLA artifacts not built (run `make artifacts`)");
            return true;
        }
        false
    }

    #[test]
    fn vec_peel_g1() {
        if skip_without_artifacts("vec_peel_g1") {
            return;
        }
        let eng = VecPeel::open_default().unwrap();
        let r = eng.try_decompose(&examples::g1()).unwrap();
        assert_eq!(r.core, examples::g1_coreness());
    }

    #[test]
    fn vec_hindex_g1() {
        if skip_without_artifacts("vec_hindex_g1") {
            return;
        }
        let eng = VecHindex::open_default().unwrap();
        let r = eng.try_decompose(&examples::g1()).unwrap();
        assert_eq!(r.core, examples::g1_coreness());
    }

    #[test]
    fn vec_engines_match_bz_on_grid() {
        if skip_without_artifacts("vec_engines_match_bz_on_grid") {
            return;
        }
        let g = gen::grid2d(8, 8); // 64 vertices, d_max 4 -> (64, 8) bucket
        let expected = bz_coreness(&g);
        let p = VecPeel::open_default().unwrap().try_decompose(&g).unwrap();
        assert_eq!(p.core, expected);
        let h = VecHindex::open_default().unwrap().try_decompose(&g).unwrap();
        assert_eq!(h.core, expected);
    }

    #[test]
    fn oversize_graph_is_structured_error() {
        if skip_without_artifacts("oversize_graph_is_structured_error") {
            return;
        }
        let g = gen::star_burst(1, 200, 0, 3); // hub degree ~200 > 64
        let eng = VecPeel::open_default().unwrap();
        let err = eng.try_decompose(&g).unwrap_err();
        assert!(err.to_string().contains("bucket"), "{err}");
    }
}
