//! Artifact registry: finds the `artifacts/` directory, reads the bucket
//! manifest, and parses HLO-text modules into `XlaComputation`s.
//! Compilation and caching of executables happens in the (thread-confined)
//! [`crate::runtime::worker`], since compiled handles are `!Send`.

use super::buckets::Bucket;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Which step function an artifact implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Kind {
    Peel,
    Hindex,
}

impl Kind {
    pub fn file_name(&self, b: Bucket) -> String {
        match self {
            Kind::Peel => format!("peel_n{}_d{}.hlo.txt", b.n, b.d),
            Kind::Hindex => format!("hindex_n{}_d{}.hlo.txt", b.n, b.d),
        }
    }
}

/// Manifest-backed artifact directory.
#[derive(Clone, Debug)]
pub struct ArtifactStore {
    dir: PathBuf,
    buckets: Vec<Bucket>,
}

impl ArtifactStore {
    /// Open an explicit directory (must contain `manifest.txt`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest.display()
            )
        })?;
        let mut buckets = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let n: usize = it
                .next()
                .context("manifest: missing N")?
                .parse()
                .with_context(|| format!("manifest line {}", i + 1))?;
            let d: usize = it
                .next()
                .context("manifest: missing D")?
                .parse()
                .with_context(|| format!("manifest line {}", i + 1))?;
            buckets.push(Bucket { n, d });
        }
        if buckets.is_empty() {
            bail!("manifest {} lists no buckets", manifest.display());
        }
        Ok(Self { dir, buckets })
    }

    /// Open the default location: `$PICO_ARTIFACTS`, else `./artifacts`,
    /// else `<crate root>/artifacts` (so `cargo test` works from anywhere).
    pub fn open_default() -> Result<Self> {
        if let Ok(dir) = std::env::var("PICO_ARTIFACTS") {
            return Self::open(dir);
        }
        let candidates = [
            PathBuf::from("artifacts"),
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        ];
        for c in &candidates {
            if c.join("manifest.txt").exists() {
                return Self::open(c);
            }
        }
        bail!("no artifacts directory found (tried $PICO_ARTIFACTS, ./artifacts); run `make artifacts`")
    }

    /// Buckets listed by the manifest.
    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    /// Read one artifact's raw HLO text (available without the `xla`
    /// feature, so missing/corrupt artifacts stay testable offline).
    pub fn load_hlo_text(&self, kind: Kind, bucket: Bucket) -> Result<String> {
        let path = self.dir.join(kind.file_name(bucket));
        std::fs::read_to_string(&path)
            .with_context(|| format!("reading artifact {}", path.display()))
    }

    /// Parse one artifact into an `XlaComputation` (thread-confined types
    /// begin here — call from the worker thread).
    #[cfg(feature = "xla")]
    pub fn load_computation(&self, kind: Kind, bucket: Bucket) -> Result<xla::XlaComputation> {
        let path = self.dir.join(kind.file_name(bucket));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF-8 artifact path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        Ok(xla::XlaComputation::from_proto(&proto))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_default_reads_manifest() {
        // Artifacts are produced by `make artifacts` (a JAX/XLA toolchain);
        // skip rather than fail when they have not been built.
        let Ok(store) = ArtifactStore::open_default() else {
            eprintln!("SKIP open_default_reads_manifest: XLA artifacts not built (run `make artifacts`)");
            return;
        };
        assert!(store.buckets().contains(&Bucket { n: 8, d: 4 }));
        assert!(store.buckets().len() >= 3);
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = ArtifactStore::open("/nonexistent_dir_xyz").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn file_names() {
        let b = Bucket { n: 8, d: 4 };
        assert_eq!(Kind::Peel.file_name(b), "peel_n8_d4.hlo.txt");
        assert_eq!(Kind::Hindex.file_name(b), "hindex_n8_d4.hlo.txt");
    }

    #[cfg(feature = "xla")]
    #[test]
    fn load_computation_parses() {
        let Ok(store) = ArtifactStore::open_default() else {
            eprintln!("SKIP load_computation_parses: XLA artifacts not built (run `make artifacts`)");
            return;
        };
        let _c = store
            .load_computation(Kind::Peel, Bucket { n: 8, d: 4 })
            .expect("parse HLO text");
    }
}
