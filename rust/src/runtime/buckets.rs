//! (N, D) bucket selection and dense padding.
//!
//! The AOT artifacts are lowered for fixed shapes; a graph runs in the
//! smallest bucket with `N >= |V|` and `D >= d_max`. Graphs exceeding the
//! largest bucket are a structured error — the coordinator falls back to
//! the native engine and says so (never silently).

use crate::graph::CsrGraph;
use anyhow::{bail, Result};

/// One compiled shape bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Bucket {
    pub n: usize,
    pub d: usize,
}

impl Bucket {
    /// Dense cells of the neighbor matrix (the memory driver).
    pub fn cells(&self) -> usize {
        self.n * self.d
    }
}

/// Pick the cheapest bucket that fits (n, d_max); `buckets` need not be
/// sorted.
pub fn select_bucket(buckets: &[Bucket], n: usize, d_max: usize) -> Result<Bucket> {
    buckets
        .iter()
        .copied()
        .filter(|b| b.n >= n && b.d >= d_max)
        .min_by_key(|b| b.cells())
        .ok_or_else(|| {
            anyhow::anyhow!(
                "no bucket fits |V|={n}, d_max={d_max} (largest: {:?}); \
                 regenerate artifacts with a bigger bucket or use the native engine",
                buckets.iter().max_by_key(|b| b.cells())
            )
        })
}

/// A graph padded into a bucket's dense shapes.
#[derive(Clone, Debug)]
pub struct PaddedGraph {
    pub bucket: Bucket,
    /// Real vertex count (<= bucket.n).
    pub n_real: usize,
    /// i32[N*D] row-major neighbor matrix, pad index = bucket.n.
    pub nbrs: Vec<i32>,
    /// i32[N] initial degrees (0 in padding).
    pub degrees: Vec<i32>,
}

impl PaddedGraph {
    pub fn new(g: &CsrGraph, buckets: &[Bucket]) -> Result<Self> {
        let n_real = g.num_vertices();
        let d_max = g.max_degree() as usize;
        let bucket = select_bucket(buckets, n_real, d_max)?;
        if n_real > i32::MAX as usize {
            bail!("graph too large for i32 indices");
        }
        let pad = bucket.n as i32;
        let mut nbrs = vec![pad; bucket.cells()];
        let mut degrees = vec![0i32; bucket.n];
        for v in 0..n_real {
            let row = v * bucket.d;
            let ns = g.neighbors(v as u32);
            degrees[v] = ns.len() as i32;
            for (j, &u) in ns.iter().enumerate() {
                nbrs[row + j] = u as i32;
            }
        }
        Ok(Self {
            bucket,
            n_real,
            nbrs,
            degrees,
        })
    }

    /// Initial alive mask (1 for real vertices with degree > 0).
    pub fn alive0(&self) -> Vec<i32> {
        self.degrees
            .iter()
            .map(|&d| if d > 0 { 1 } else { 0 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::examples;

    fn bs() -> Vec<Bucket> {
        [(8, 4), (64, 8), (256, 16), (1024, 32), (4096, 64)]
            .iter()
            .map(|&(n, d)| Bucket { n, d })
            .collect()
    }

    #[test]
    fn selects_smallest_fitting() {
        assert_eq!(select_bucket(&bs(), 6, 4).unwrap(), Bucket { n: 8, d: 4 });
        assert_eq!(select_bucket(&bs(), 6, 5).unwrap(), Bucket { n: 64, d: 8 });
        assert_eq!(select_bucket(&bs(), 100, 8).unwrap(), Bucket { n: 256, d: 16 });
    }

    #[test]
    fn rejects_oversize() {
        assert!(select_bucket(&bs(), 5000, 4).is_err());
        assert!(select_bucket(&bs(), 4, 100).is_err());
    }

    #[test]
    fn pads_g1() {
        let g = examples::g1();
        let p = PaddedGraph::new(&g, &bs()).unwrap();
        assert_eq!(p.bucket, Bucket { n: 8, d: 4 });
        assert_eq!(p.n_real, 6);
        assert_eq!(p.degrees, vec![1, 1, 2, 3, 3, 4, 0, 0]);
        // v5's row: neighbors 0,1,3,4
        assert_eq!(&p.nbrs[5 * 4..6 * 4], &[0, 1, 3, 4]);
        // padding rows are all-pad
        assert_eq!(&p.nbrs[6 * 4..7 * 4], &[8, 8, 8, 8]);
        assert_eq!(p.alive0(), vec![1, 1, 1, 1, 1, 1, 0, 0]);
    }
}
