//! The XLA service thread.
//!
//! PJRT handles from the `xla` crate are `Rc`-based and thread-confined,
//! so one dedicated worker thread owns the client, the compiled-executable
//! cache, and the step-driver loops; the rest of the system talks to it
//! through a channel. The handle ([`XlaWorker`]) is `Send + Sync` and
//! cheap to clone behind an `Arc` — this is also exactly the shape a
//! GPU-backed deployment would have (one host thread owning the CUDA
//! context, a queue in front).

use super::artifacts::{ArtifactStore, Kind};
use super::buckets::{Bucket, PaddedGraph};
use crate::core::traits::DecompositionResult;
use crate::engine::metrics::MetricsSnapshot;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::mpsc;

enum Request {
    Decompose {
        kind: Kind,
        padded: PaddedGraph,
        reply: mpsc::Sender<Result<DecompositionResult>>,
    },
    Platform {
        reply: mpsc::Sender<Result<String>>,
    },
    Shutdown,
}

/// Handle to the XLA service thread.
pub struct XlaWorker {
    tx: mpsc::Sender<Request>,
    join: std::sync::Mutex<Option<std::thread::JoinHandle<()>>>,
    store: ArtifactStore,
}

impl XlaWorker {
    /// Spawn the service thread over an artifact store.
    pub fn spawn(store: ArtifactStore) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Request>();
        let thread_store = store.clone();
        let join = std::thread::Builder::new()
            .name("pico-xla-worker".into())
            .spawn(move || worker_main(thread_store, rx))
            .context("spawning XLA worker thread")?;
        Ok(Self {
            tx,
            join: std::sync::Mutex::new(Some(join)),
            store,
        })
    }

    /// Spawn against the default artifact location.
    pub fn spawn_default() -> Result<Self> {
        Self::spawn(ArtifactStore::open_default()?)
    }

    /// Buckets available (manifest).
    pub fn buckets(&self) -> &[Bucket] {
        self.store.buckets()
    }

    /// Pad `g` and run one decomposition on the service thread.
    pub fn decompose(&self, kind: Kind, g: &crate::graph::CsrGraph) -> Result<DecompositionResult> {
        let padded = PaddedGraph::new(g, self.store.buckets())?;
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Decompose {
                kind,
                padded,
                reply,
            })
            .map_err(|_| anyhow::anyhow!("XLA worker thread is gone"))?;
        rx.recv().context("XLA worker dropped the reply")?
    }

    /// Platform description from the worker's client.
    pub fn platform(&self) -> Result<String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Platform { reply })
            .map_err(|_| anyhow::anyhow!("XLA worker thread is gone"))?;
        rx.recv().context("XLA worker dropped the reply")?
    }
}

impl Drop for XlaWorker {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.lock().unwrap().take() {
            let _ = j.join();
        }
    }
}

/// Worker thread main: owns client + executable cache, serves requests.
fn worker_main(store: ArtifactStore, rx: mpsc::Receiver<Request>) {
    let client = super::client::create_cpu_client();
    let mut cache: HashMap<(Kind, Bucket), xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Platform { reply } => {
                let msg = client
                    .as_ref()
                    .map(|c| super::client::platform_info(c))
                    .map_err(|e| anyhow::anyhow!("{e}"));
                let _ = reply.send(msg);
            }
            Request::Decompose {
                kind,
                padded,
                reply,
            } => {
                let out = (|| -> Result<DecompositionResult> {
                    let client = client
                        .as_ref()
                        .map_err(|e| anyhow::anyhow!("PJRT client unavailable: {e}"))?;
                    let key = (kind, padded.bucket);
                    if !cache.contains_key(&key) {
                        let comp = store.load_computation(kind, padded.bucket)?;
                        let exe = client
                            .compile(&comp)
                            .map_err(|e| anyhow::anyhow!("compiling {kind:?} {:?}: {e}", padded.bucket))?;
                        cache.insert(key, exe);
                    }
                    let exe = &cache[&key];
                    match kind {
                        Kind::Peel => drive_peel(exe, &padded),
                        Kind::Hindex => drive_hindex(exe, &padded),
                    }
                })();
                let _ = reply.send(out);
            }
        }
    }
}

/// Drive the vectorised PeelOne to convergence.
fn drive_peel(exe: &xla::PjRtLoadedExecutable, padded: &PaddedGraph) -> Result<DecompositionResult> {
    let n = padded.bucket.n;
    let d = padded.bucket.d;
    let nbrs = xla::Literal::vec1(&padded.nbrs)
        .reshape(&[n as i64, d as i64])
        .map_err(|e| anyhow::anyhow!("reshape: {e}"))?;
    let mut core = padded.degrees.clone();
    let mut alive = padded.alive0();
    let mut total_alive: i64 = alive.iter().map(|&a| a as i64).sum();
    let mut k: i32 = 1;
    let mut iterations = 0usize;
    let mut launches = 0usize;

    while total_alive > 0 {
        if k as usize > d + 1 {
            bail!("vectorised peel failed to converge (k={k} > D+1)");
        }
        let core_lit = xla::Literal::vec1(&core);
        let alive_lit = xla::Literal::vec1(&alive);
        let k_lit = xla::Literal::scalar(k);
        let out = exe
            .execute::<&xla::Literal>(&[&core_lit, &alive_lit, &nbrs, &k_lit])
            .map_err(|e| anyhow::anyhow!("peel execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("peel sync: {e}"))?;
        let (c, a, fc, ac) = out
            .to_tuple4()
            .map_err(|e| anyhow::anyhow!("peel tuple: {e}"))?;
        core = c.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        alive = a.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        let frontier: i32 = fc
            .get_first_element()
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let alive_now: i32 = ac
            .get_first_element()
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        launches += 1;
        if frontier == 0 {
            k += 1;
        } else {
            iterations += 1;
        }
        total_alive = alive_now as i64;
    }

    Ok(DecompositionResult {
        core: core[..padded.n_real].iter().map(|&c| c as u32).collect(),
        iterations,
        launches,
        metrics: MetricsSnapshot::default(),
    })
}

/// Drive the vectorised h-index iteration to convergence.
fn drive_hindex(
    exe: &xla::PjRtLoadedExecutable,
    padded: &PaddedGraph,
) -> Result<DecompositionResult> {
    let n = padded.bucket.n;
    let d = padded.bucket.d;
    let nbrs = xla::Literal::vec1(&padded.nbrs)
        .reshape(&[n as i64, d as i64])
        .map_err(|e| anyhow::anyhow!("reshape: {e}"))?;
    let mut core = padded.degrees.clone();
    let mut iterations = 0usize;

    loop {
        if iterations > n + 2 {
            bail!("vectorised h-index failed to converge");
        }
        let core_lit = xla::Literal::vec1(&core);
        let out = exe
            .execute::<&xla::Literal>(&[&core_lit, &nbrs])
            .map_err(|e| anyhow::anyhow!("hindex execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("hindex sync: {e}"))?;
        let (c, ch) = out
            .to_tuple2()
            .map_err(|e| anyhow::anyhow!("hindex tuple: {e}"))?;
        core = c.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        let changed: i32 = ch
            .get_first_element()
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        iterations += 1;
        if changed == 0 {
            break;
        }
    }

    Ok(DecompositionResult {
        core: core[..padded.n_real].iter().map(|&c| c as u32).collect(),
        iterations,
        launches: iterations,
        metrics: MetricsSnapshot::default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::examples;

    #[test]
    fn worker_round_trip() {
        let Ok(w) = XlaWorker::spawn_default() else {
            eprintln!("SKIP worker_round_trip: XLA artifacts not built (run `make artifacts`)");
            return;
        };
        assert!(w.platform().unwrap().to_lowercase().contains("cpu"));
        let r = w.decompose(Kind::Peel, &examples::g1()).unwrap();
        assert_eq!(r.core, examples::g1_coreness());
        let r = w.decompose(Kind::Hindex, &examples::g1()).unwrap();
        assert_eq!(r.core, examples::g1_coreness());
    }

    #[test]
    fn worker_usable_from_many_threads() {
        let Ok(worker) = XlaWorker::spawn_default() else {
            eprintln!("SKIP worker_usable_from_many_threads: XLA artifacts not built (run `make artifacts`)");
            return;
        };
        let w = std::sync::Arc::new(worker);
        let mut handles = Vec::new();
        for _ in 0..4 {
            let w = w.clone();
            handles.push(std::thread::spawn(move || {
                w.decompose(Kind::Peel, &examples::g1()).unwrap().core
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), examples::g1_coreness());
        }
    }
}
