//! PJRT runtime — loads the AOT artifacts produced by `make artifacts`
//! (HLO text; see python/compile/aot.py for why text, not protos) and
//! executes them on the XLA CPU client from the rust request path.
//!
//! * [`client`] — process-wide PJRT client handle.
//! * [`artifacts`] — artifact registry: manifest parsing, lazy
//!   compile-and-cache of the per-bucket executables.
//! * [`buckets`] — (N, D) bucket selection and dense padding of CSR
//!   graphs into the fixed shapes the artifacts were lowered for.
//! * [`vec_engine`] — the vectorised decomposition engines (VETGA [20]
//!   lineage): [`vec_engine::VecPeel`] and [`vec_engine::VecHindex`],
//!   both [`crate::core::Decomposer`]s, proving the three layers compose.

//! The PJRT-backed pieces ([`client`], [`worker`], [`vec_engine`]) need the
//! `xla` crate, which the offline build environment does not carry; they are
//! gated behind the `xla` cargo feature. [`artifacts`] and [`buckets`]
//! (manifest parsing, shape selection, dense padding) are pure Rust and stay
//! available unconditionally so their error paths remain testable.

pub mod artifacts;
pub mod buckets;
#[cfg(feature = "xla")]
pub mod client;
#[cfg(feature = "xla")]
pub mod vec_engine;
#[cfg(feature = "xla")]
pub mod worker;

pub use artifacts::ArtifactStore;
pub use buckets::{select_bucket, Bucket, PaddedGraph};
#[cfg(feature = "xla")]
pub use vec_engine::{default_worker, VecHindex, VecPeel};
#[cfg(feature = "xla")]
pub use worker::XlaWorker;
