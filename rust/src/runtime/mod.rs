//! PJRT runtime — loads the AOT artifacts produced by `make artifacts`
//! (HLO text; see python/compile/aot.py for why text, not protos) and
//! executes them on the XLA CPU client from the rust request path.
//!
//! * [`client`] — process-wide PJRT client handle.
//! * [`artifacts`] — artifact registry: manifest parsing, lazy
//!   compile-and-cache of the per-bucket executables.
//! * [`buckets`] — (N, D) bucket selection and dense padding of CSR
//!   graphs into the fixed shapes the artifacts were lowered for.
//! * [`vec_engine`] — the vectorised decomposition engines (VETGA [20]
//!   lineage): [`vec_engine::VecPeel`] and [`vec_engine::VecHindex`],
//!   both [`crate::core::Decomposer`]s, proving the three layers compose.

pub mod artifacts;
pub mod buckets;
pub mod client;
pub mod vec_engine;
pub mod worker;

pub use artifacts::ArtifactStore;
pub use buckets::{select_bucket, Bucket, PaddedGraph};
pub use vec_engine::{default_worker, VecHindex, VecPeel};
pub use worker::XlaWorker;
