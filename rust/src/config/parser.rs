//! Minimal INI-style parser: `key = value` lines, `[section]` headers
//! prefixing subsequent keys as `section.key`, `#`/`;` comments. Built
//! in-tree because the environment is offline (no serde/toml crates).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Parsed key-value file with section-qualified keys.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct KvFile {
    entries: BTreeMap<String, String>,
}

impl KvFile {
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected 'key = value', got '{line}'", lineno + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let mut value = v.trim();
            // strip optional quotes
            if value.len() >= 2 && value.starts_with('"') && value.ends_with('"') {
                value = &value[1..value.len() - 1];
            }
            if entries.insert(key.clone(), value.to_string()).is_some() {
                bail!("line {}: duplicate key '{key}'", lineno + 1);
            }
        }
        Ok(Self { entries })
    }

    pub fn parse_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.as_ref().display()))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let kv = KvFile::parse(
            "# top\nthreads = 4\n[bench]\n; c\nreps = 5\nsuite = \"small\"\n",
        )
        .unwrap();
        assert_eq!(kv.get("threads"), Some("4"));
        assert_eq!(kv.get("bench.reps"), Some("5"));
        assert_eq!(kv.get("bench.suite"), Some("small"));
        assert_eq!(kv.get("missing"), None);
    }

    #[test]
    fn rejects_malformed() {
        assert!(KvFile::parse("just a line\n").is_err());
        assert!(KvFile::parse("[open\n").is_err());
        assert!(KvFile::parse("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn empty_ok() {
        assert!(KvFile::parse("").unwrap().keys().next().is_none());
    }
}
