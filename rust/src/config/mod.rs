//! Configuration: defaults < config file (`pico.conf`, INI-like) < env
//! vars < CLI flags. The launcher (`pico`) and the bench binaries all
//! resolve their knobs through [`Config`].

pub mod parser;

use anyhow::{Context, Result};
use parser::KvFile;
use std::path::Path;

/// Resolved runtime configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    /// SPMD worker threads per decomposition.
    pub threads: usize,
    /// Timed repetitions per bench measurement.
    pub bench_reps: usize,
    /// Suite tier name (small | standard | large | xla).
    pub suite_tier: String,
    /// Scheduler memory budget in bytes.
    pub memory_budget: u64,
    /// Artifacts directory override (empty = default resolution).
    pub artifacts_dir: String,
    /// Base seed for generated workloads.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            threads: crate::util::default_threads(),
            bench_reps: 3,
            suite_tier: "standard".into(),
            memory_budget: 8 << 30,
            artifacts_dir: String::new(),
            seed: 42,
        }
    }
}

impl Config {
    /// Layer a parsed key-value file on top of `self`.
    pub fn apply_file(&mut self, kv: &KvFile) -> Result<()> {
        if let Some(v) = kv.get("threads") {
            self.threads = v.parse().context("threads")?;
        }
        if let Some(v) = kv.get("bench.reps") {
            self.bench_reps = v.parse().context("bench.reps")?;
        }
        if let Some(v) = kv.get("bench.suite") {
            self.suite_tier = v.to_string();
        }
        if let Some(v) = kv.get("scheduler.memory_budget_mb") {
            let mb: u64 = v.parse().context("scheduler.memory_budget_mb")?;
            self.memory_budget = mb << 20;
        }
        if let Some(v) = kv.get("runtime.artifacts") {
            self.artifacts_dir = v.to_string();
        }
        if let Some(v) = kv.get("seed") {
            self.seed = v.parse().context("seed")?;
        }
        Ok(())
    }

    /// Layer environment variables on top.
    pub fn apply_env(&mut self) {
        if let Ok(v) = std::env::var("PICO_THREADS") {
            if let Ok(n) = v.parse() {
                self.threads = n;
            }
        }
        if let Ok(v) = std::env::var("PICO_BENCH_REPS") {
            if let Ok(n) = v.parse() {
                self.bench_reps = n;
            }
        }
        if let Ok(v) = std::env::var("PICO_SUITE") {
            self.suite_tier = v;
        }
        if let Ok(v) = std::env::var("PICO_ARTIFACTS") {
            self.artifacts_dir = v;
        }
    }

    /// Full resolution: defaults, optional file, env.
    pub fn load(path: Option<&Path>) -> Result<Self> {
        let mut cfg = Self::default();
        let candidate = path
            .map(|p| p.to_path_buf())
            .unwrap_or_else(|| "pico.conf".into());
        if candidate.exists() {
            let kv = KvFile::parse_file(&candidate)?;
            cfg.apply_file(&kv)?;
        } else if path.is_some() {
            anyhow::bail!("config file {} not found", candidate.display());
        }
        cfg.apply_env();
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = Config::default();
        assert!(c.threads >= 1);
        assert_eq!(c.suite_tier, "standard");
    }

    #[test]
    fn file_layering() {
        let kv = KvFile::parse(
            "threads = 7\n[bench]\nreps = 9\nsuite = small\n[scheduler]\nmemory_budget_mb = 64\n",
        )
        .unwrap();
        let mut c = Config::default();
        c.apply_file(&kv).unwrap();
        assert_eq!(c.threads, 7);
        assert_eq!(c.bench_reps, 9);
        assert_eq!(c.suite_tier, "small");
        assert_eq!(c.memory_budget, 64 << 20);
    }

    #[test]
    fn bad_value_is_error() {
        let kv = KvFile::parse("threads = lots\n").unwrap();
        assert!(Config::default().apply_file(&kv).is_err());
    }

    #[test]
    fn missing_explicit_file_errors() {
        assert!(Config::load(Some(Path::new("/no/such/pico.conf"))).is_err());
    }
}
