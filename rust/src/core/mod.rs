//! Core decomposition algorithms — the paper's contribution plus every
//! baseline it compares against, and the serving-era kernels grown on
//! top of them.
//!
//! The **Registry** column is the name `coordinator::registry` resolves
//! (CI greps the two lists against each other, so a kernel cannot land
//! in the registry without a row here).
//!
//! | Registry | Algorithm | Paradigm | Role |
//! |---|---|---|---|
//! | `BZ` | [`bz::Bz`] | serial Peel | O(M) ground-truth oracle [33] |
//! | `GPP` | [`peel::Gpp`] | Peel | General Parallel Peel baseline (Alg 3) |
//! | `PeelOne` | [`peel::PeelOne`] | Peel | **proposed** — assertion method (Alg 4) |
//! | `PP-dyn` | [`peel::PpDyn`] | Peel | SOTA dynamic-frontier baseline [21] |
//! | `PO-dyn` | [`peel::PoDyn`] | Peel | **proposed** — PeelOne + dynamic frontier |
//! | `BucketPeel` | [`peel::BucketPeel`] | Peel | hierarchical log-spaced buckets with per-bucket local frontiers (theory-practice, Liu & Dong) — the flush-time recompute kernel |
//! | `VC-Peel(Gunrock)` | [`crate::vc::VcPeel`] | Peel | vertex-centric framework baseline (§V) |
//! | `NbrCore` | [`index2core::NbrCore`] | Index2core | baseline [19] |
//! | `CntCore` | [`index2core::CntCore`] | Index2core | **proposed** — cnt frontiers (Alg 5) |
//! | `HistoCore` | [`index2core::HistoCore`] | Index2core | **proposed** — up-to-date histograms (Alg 6) |
//! | `Hybrid` | [`hybrid::Hybrid`] | either | density-based paradigm pick (§VI) |
//! | `VecPeel(XLA)` | `runtime::xla` | Peel | vectorised peel via the XLA backend (feature-gated) |
//! | `VecHindex(XLA)` | `runtime::xla` | Index2core | vectorised h-index via the XLA backend (feature-gated) |
//!
//! Not in the registry (not a full decomposition): [`peel::single_k`],
//! the sort-free single-k extractor (Xiang) behind the `MEMBERS` fast
//! path — it produces one level set in O(n+m) instead of all of them.

pub mod bz;
pub mod hindex;
pub mod hybrid;
pub mod index2core;
pub mod maintenance;
pub mod peel;
pub mod traits;
pub mod verify;

pub use hybrid::Hybrid;
pub use maintenance::{DynamicCore, EdgeEdit};
pub use traits::{DecompositionResult, Decomposer, Paradigm};
