//! Core decomposition algorithms — the paper's contribution plus every
//! baseline it compares against.
//!
//! | Algorithm | Paradigm | Paper role |
//! |---|---|---|
//! | [`bz::Bz`] | serial Peel | O(M) ground-truth oracle [33] |
//! | [`peel::Gpp`] | Peel | General Parallel Peel baseline (Alg 3) |
//! | [`peel::PeelOne`] | Peel | **proposed** — assertion method (Alg 4) |
//! | [`peel::PpDyn`] | Peel | SOTA dynamic-frontier baseline [21] |
//! | [`peel::PoDyn`] | Peel | **proposed** — PeelOne + dynamic frontier |
//! | [`index2core::NbrCore`] | Index2core | baseline [19] |
//! | [`index2core::CntCore`] | Index2core | **proposed** — cnt frontiers (Alg 5) |
//! | [`index2core::HistoCore`] | Index2core | **proposed** — up-to-date histograms (Alg 6) |

pub mod bz;
pub mod hindex;
pub mod hybrid;
pub mod index2core;
pub mod maintenance;
pub mod peel;
pub mod traits;
pub mod verify;

pub use hybrid::Hybrid;
pub use maintenance::{DynamicCore, EdgeEdit};
pub use traits::{DecompositionResult, Decomposer, Paradigm};
