//! The common decomposition interface and its instrumented result type.

use crate::engine::metrics::MetricsSnapshot;
use crate::graph::CsrGraph;
use crate::util::default_threads;

/// Which of the paper's paradigms an algorithm belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Paradigm {
    /// Bottom-up iterative removal (§II-A, Algorithm 1).
    Peel,
    /// Top-down h-index convergence (§II-A, Algorithm 2).
    Index2core,
    /// Serial reference (BZ).
    Serial,
    /// Dense vectorised engine executed through XLA (VETGA lineage).
    Vectorized,
}

/// Output of a decomposition run, carrying the columns the paper's tables
/// report alongside the coreness itself.
#[derive(Clone, Debug)]
pub struct DecompositionResult {
    /// `core[v]` = coreness of vertex `v`.
    pub core: Vec<u32>,
    /// The paper's iteration count — l1 for Peel algorithms (scan/scatter
    /// rounds), l2 for Index2core (convergence sweeps).
    pub iterations: usize,
    /// BSP kernel launches (barrier-delimited phases).
    pub launches: usize,
    /// Instrumented counters (zeros when metrics were disabled).
    pub metrics: MetricsSnapshot,
}

impl DecompositionResult {
    /// Max coreness (the dataset's k_max).
    pub fn k_max(&self) -> u32 {
        self.core.iter().copied().max().unwrap_or(0)
    }
}

/// A k-core decomposition algorithm.
pub trait Decomposer: Sync {
    /// Display name used in tables (`PeelOne`, `HistoCore`, …).
    fn name(&self) -> &'static str;

    fn paradigm(&self) -> Paradigm;

    /// Run with explicit thread count and metrics switch.
    fn decompose_with(&self, g: &CsrGraph, threads: usize, metrics: bool) -> DecompositionResult;

    /// Run with defaults (host parallelism, metrics off).
    fn decompose(&self, g: &CsrGraph) -> DecompositionResult {
        self.decompose_with(g, default_threads(), false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kmax_of_result() {
        let r = DecompositionResult {
            core: vec![1, 3, 2],
            iterations: 0,
            launches: 0,
            metrics: MetricsSnapshot::default(),
        };
        assert_eq!(r.k_max(), 3);
    }

    #[test]
    fn kmax_empty() {
        let r = DecompositionResult {
            core: vec![],
            iterations: 0,
            launches: 0,
            metrics: MetricsSnapshot::default(),
        };
        assert_eq!(r.k_max(), 0);
    }
}
