//! Decomposition validation: structural invariants plus oracle agreement.
//! Every bench run validates its output here, so any table row reported in
//! EXPERIMENTS.md is backed by a correctness check against BZ.

use super::bz::bz_coreness;
use super::hindex::hindex;
use crate::graph::CsrGraph;

/// Structural invariants a coreness vector must satisfy, checkable without
/// an oracle:
/// 1. `core[v] <= deg(v)`;
/// 2. *support*: at least `core[v]` neighbors have coreness ≥ `core[v]`
///    (v's membership in its own k-core);
/// 3. *h-index fixpoint*: `H(core of neighbors) == core[v]` — coreness is
///    the (maximal) fixpoint of the h-index operator [18].
pub fn check_invariants(g: &CsrGraph, core: &[u32]) -> Result<(), String> {
    if core.len() != g.num_vertices() {
        return Err(format!(
            "length mismatch: |core|={} but |V|={}",
            core.len(),
            g.num_vertices()
        ));
    }
    for v in 0..g.num_vertices() {
        let cv = core[v];
        let deg = g.degree(v as u32);
        if cv > deg {
            return Err(format!("core[{v}]={cv} exceeds degree {deg}"));
        }
        let nbr_cores: Vec<u32> = g
            .neighbors(v as u32)
            .iter()
            .map(|&u| core[u as usize])
            .collect();
        let support = nbr_cores.iter().filter(|&&c| c >= cv).count() as u32;
        if support < cv {
            return Err(format!(
                "core[{v}]={cv} has only {support} supporting neighbors"
            ));
        }
        let h = hindex(&nbr_cores);
        if h != cv {
            return Err(format!(
                "h-index fixpoint violated at {v}: H(nbrs)={h}, core={cv}"
            ));
        }
    }
    Ok(())
}

/// Full validation: invariants + exact agreement with the BZ oracle.
pub fn check_against_oracle(g: &CsrGraph, core: &[u32]) -> Result<(), String> {
    check_invariants(g, core)?;
    let expected = bz_coreness(g);
    if core != expected.as_slice() {
        let diff = core
            .iter()
            .zip(&expected)
            .enumerate()
            .find(|(_, (a, b))| a != b)
            .map(|(v, (a, b))| format!("first mismatch at v{v}: got {a}, expected {b}"))
            .unwrap_or_default();
        return Err(format!("oracle mismatch: {diff}"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::examples;

    #[test]
    fn correct_coreness_passes() {
        let g = examples::g1();
        assert_eq!(check_against_oracle(&g, &examples::g1_coreness()), Ok(()));
    }

    #[test]
    fn rejects_wrong_values() {
        let g = examples::g1();
        let mut core = examples::g1_coreness();
        core[0] = 2;
        assert!(check_invariants(&g, &core).is_err());
        let mut core = examples::g1_coreness();
        core[5] = 1; // h-index fixpoint violated (too low)
        assert!(check_invariants(&g, &core).is_err());
    }

    #[test]
    fn rejects_length_mismatch() {
        let g = examples::g1();
        assert!(check_invariants(&g, &[1, 1]).is_err());
    }

    #[test]
    fn rejects_above_degree() {
        let g = examples::path(3);
        assert!(check_invariants(&g, &[2, 2, 2]).is_err());
    }
}
