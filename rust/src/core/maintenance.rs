//! Core maintenance on dynamic graphs — the paper's §VI-C1 variant: keep
//! every vertex's coreness current under edge insertions/deletions
//! without recomputing the whole graph.
//!
//! Implements the classic subcore/traversal approach ([47], Sariyüce et
//! al.): a single edge edit changes coreness by at most one, and only
//! within the *k-subcore* — the set of vertices with coreness exactly
//! `k = min(core(u), core(v))` connected to the edited edge through
//! vertices of that same coreness.
//!
//! * **Insertion**: collect the subcore S reachable from the lower-core
//!   endpoint(s); compute each member's *candidate degree* (neighbors
//!   with higher core or inside S); iteratively evict members with
//!   cd ≤ k; survivors are promoted to k+1.
//! * **Deletion**: collect the subcore after removing the edge; compute
//!   each member's *max-core degree* (neighbors with core ≥ k); cascade
//!   demotions of members whose mcd falls below k.
//!
//! Every operation is verified in tests against a from-scratch BZ run on
//! randomised edit scripts.

use crate::core::bz::bz_coreness;
use crate::core::traits::Decomposer;
use crate::graph::{CsrGraph, GraphBuilder, VertexId};
use std::collections::{HashMap, HashSet};

/// One edge edit. Endpoints are unordered (stored as given, compared
/// canonically); self-loop edits are rejected by [`DynamicCore::apply`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EdgeEdit {
    Insert(VertexId, VertexId),
    Delete(VertexId, VertexId),
}

impl EdgeEdit {
    /// Canonical `(min, max)` endpoint pair — the coalescing key.
    pub fn endpoints(&self) -> (VertexId, VertexId) {
        match *self {
            EdgeEdit::Insert(u, v) | EdgeEdit::Delete(u, v) => (u.min(v), u.max(v)),
        }
    }

    pub fn is_insert(&self) -> bool {
        matches!(self, EdgeEdit::Insert(_, _))
    }
}

/// Hoisted work queues for the subcore/traversal maintenance — one set
/// per index, reused across edits and batches instead of reallocated per
/// call (the incremental half of the scratch-reuse audit; the recompute
/// half is [`crate::core::peel::BucketScratch`]). Buffers are cleared at
/// each use and never shrink; reuses are counted in
/// [`crate::engine::metrics::scratch_reuses`].
#[derive(Clone, Debug, Default)]
struct MaintScratch {
    /// Subcore DFS: visited set, stack, and collected output.
    seen: HashSet<VertexId>,
    stack: Vec<VertexId>,
    sub: Vec<VertexId>,
    /// Candidate bookkeeping: member → slot, cd/mcd degrees,
    /// evicted/demoted flags, cascade queue.
    index: HashMap<VertexId, usize>,
    deg: Vec<u32>,
    flag: Vec<bool>,
    queue: Vec<usize>,
}

/// The subcore of level `k` reachable from `roots` (vertices with
/// core == k, connected through vertices of core == k), collected into
/// `scratch.sub`. A free function over the fields so callers can keep
/// disjoint borrows on `adj`/`core` while the scratch is held mutably.
fn subcore_into(
    adj: &[Vec<VertexId>],
    core: &[u32],
    k: u32,
    roots: &[VertexId],
    scratch: &mut MaintScratch,
) {
    if scratch.stack.capacity() > 0 {
        // warm buffers from an earlier edit: this call allocates nothing
        crate::engine::metrics::note_scratch_reuses(1);
    }
    scratch.seen.clear();
    scratch.stack.clear();
    scratch.sub.clear();
    for &r in roots {
        if core[r as usize] == k && scratch.seen.insert(r) {
            scratch.stack.push(r);
        }
    }
    while let Some(w) = scratch.stack.pop() {
        scratch.sub.push(w);
        for &x in &adj[w as usize] {
            if core[x as usize] == k && scratch.seen.insert(x) {
                scratch.stack.push(x);
            }
        }
    }
}

/// A mutable graph with continuously maintained coreness.
#[derive(Clone, Debug)]
pub struct DynamicCore {
    adj: Vec<Vec<VertexId>>,
    core: Vec<u32>,
    scratch: MaintScratch,
}

impl DynamicCore {
    /// Initialise from a static graph (one BZ run).
    pub fn new(g: &CsrGraph) -> Self {
        let adj = (0..g.num_vertices() as VertexId)
            .map(|v| g.neighbors(v).to_vec())
            .collect();
        Self {
            adj,
            core: bz_coreness(g),
            scratch: MaintScratch::default(),
        }
    }

    /// Empty graph with `n` vertices.
    pub fn with_vertices(n: usize) -> Self {
        Self {
            adj: vec![Vec::new(); n],
            core: vec![0; n],
            scratch: MaintScratch::default(),
        }
    }

    /// Hydrate from a shipped (graph, coreness) pair — **no**
    /// decomposition runs. The caller vouches for `core` (the snapshot
    /// decoder validates it against the coreness invariants before
    /// handing it here).
    pub fn from_parts(g: &CsrGraph, core: Vec<u32>) -> Self {
        assert_eq!(
            core.len(),
            g.num_vertices(),
            "coreness length must match the vertex count"
        );
        let adj = (0..g.num_vertices() as VertexId)
            .map(|v| g.neighbors(v).to_vec())
            .collect();
        Self {
            adj,
            core,
            scratch: MaintScratch::default(),
        }
    }

    pub fn num_vertices(&self) -> usize {
        self.adj.len()
    }

    /// Undirected edge count. O(|V|): sums adjacency lengths.
    pub fn num_edges(&self) -> u64 {
        self.adj.iter().map(|a| a.len() as u64).sum::<u64>() / 2
    }

    pub fn coreness(&self) -> &[u32] {
        &self.core
    }

    /// Grow the vertex set so `v` is a valid id (new vertices are
    /// isolated with coreness 0).
    pub fn ensure_vertex(&mut self, v: VertexId) {
        let need = v as usize + 1;
        if need > self.adj.len() {
            self.adj.resize(need, Vec::new());
            self.core.resize(need, 0);
        }
    }

    pub fn has_edge(&self, u: VertexId, v: VertexId) -> bool {
        self.adj[u as usize].contains(&v)
    }

    /// Current degree of `v` — O(1), no CSR rebuild (delta replay
    /// validates refined corenesses against it per entry).
    pub fn degree(&self, v: VertexId) -> u32 {
        self.adj[v as usize].len() as u32
    }

    /// Rebuild an immutable CSR snapshot (for oracle checks / export).
    pub fn snapshot(&self) -> CsrGraph {
        let mut b = GraphBuilder::new(self.num_vertices());
        for (u, nbrs) in self.adj.iter().enumerate() {
            for &v in nbrs {
                if (u as VertexId) < v {
                    b.add_edge(u as VertexId, v);
                }
            }
        }
        b.build("dynamic-snapshot")
    }

    /// Adjacency of `v` — the live structure, no CSR rebuild (the
    /// single-k overlay iterates it per query).
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        &self.adj[v as usize]
    }

    /// Mutate the adjacency only — no coreness maintenance. Returns true
    /// if the edge was new. Pair with [`Self::recompute_with`]; used by
    /// the service batch path when a full recompute is cheaper than
    /// cascading per-edit maintenance.
    pub fn insert_edge_structural(&mut self, u: VertexId, v: VertexId) -> bool {
        if u == v {
            return false;
        }
        let (u, v) = (u.min(v), u.max(v));
        if self.has_edge(u, v) {
            return false;
        }
        self.adj[u as usize].push(v);
        self.adj[v as usize].push(u);
        true
    }

    /// Structural counterpart of [`Self::delete_edge`]; returns true if
    /// the edge existed. No coreness maintenance.
    pub fn delete_edge_structural(&mut self, u: VertexId, v: VertexId) -> bool {
        let (u, v) = (u.min(v), u.max(v));
        let Some(pu) = self.adj[u as usize].iter().position(|&x| x == v) else {
            return false;
        };
        self.adj[u as usize].swap_remove(pu);
        let pv = self.adj[v as usize]
            .iter()
            .position(|&x| x == u)
            .expect("asymmetric adjacency");
        self.adj[v as usize].swap_remove(pv);
        true
    }

    /// Replace the maintained coreness with a from-scratch run of `algo`
    /// over the current structure (the batch path's recompute fallback).
    pub fn recompute_with(&mut self, algo: &dyn Decomposer, threads: usize) {
        let g = self.snapshot();
        self.core = algo.decompose_with(&g, threads, false).core;
    }

    /// Recompute via the hierarchical-bucket peel
    /// ([`crate::core::peel::BucketPeel`]) with a caller-held scratch —
    /// the serving layer's flush-time recompute hot path. A warm scratch
    /// skips all five O(|V|) allocations; reuses tick
    /// [`crate::engine::metrics::scratch_reuses`].
    pub fn recompute_bucket(
        &mut self,
        threads: usize,
        scratch: &mut crate::core::peel::BucketScratch,
    ) {
        let g = self.snapshot();
        let n = g.num_vertices();
        if scratch.ensure(n) {
            crate::engine::metrics::note_scratch_reuses(1);
        }
        let metrics = crate::engine::metrics::Metrics::disabled(threads.max(1));
        crate::core::peel::bucket_peel_into(&g, threads, &metrics, scratch);
        scratch.copy_core_into(n, &mut self.core);
    }

    /// Apply one [`EdgeEdit`] with incremental maintenance. Returns true
    /// if the edge set changed (self-loop edits never do).
    pub fn apply(&mut self, edit: EdgeEdit) -> bool {
        match edit {
            EdgeEdit::Insert(u, v) => {
                if u == v {
                    return false;
                }
                self.insert_edge(u, v)
            }
            EdgeEdit::Delete(u, v) => self.delete_edge(u, v),
        }
    }

    /// Apply a batch of edits through the incremental path. Returns how
    /// many edits actually changed the edge set. For batches large enough
    /// that maintenance cascades dominate, prefer the structural edits +
    /// [`Self::recompute_with`] combination (see `service::batch` for the
    /// crossover policy).
    pub fn apply_batch(&mut self, edits: &[EdgeEdit]) -> usize {
        edits.iter().filter(|&&e| self.apply(e)).count()
    }

    /// Insert an undirected edge; returns true if it was new.
    /// Amortised cost is proportional to the affected subcore, not |G|.
    pub fn insert_edge(&mut self, u: VertexId, v: VertexId) -> bool {
        assert!(u != v, "self-loops unsupported");
        let (u, v) = (u.min(v), u.max(v));
        if !self.insert_edge_structural(u, v) {
            return false;
        }

        let (cu, cv) = (self.core[u as usize], self.core[v as usize]);
        let k = cu.min(cv);
        // roots: endpoints sitting exactly at level k
        let mut roots = [0 as VertexId; 2];
        let mut nr = 0usize;
        for w in [u, v] {
            if self.core[w as usize] == k {
                roots[nr] = w;
                nr += 1;
            }
        }
        subcore_into(&self.adj, &self.core, k, &roots[..nr], &mut self.scratch);
        if self.scratch.sub.is_empty() {
            return true;
        }

        let MaintScratch {
            sub: candidates,
            index,
            deg: cd,
            flag: evicted,
            queue,
            ..
        } = &mut self.scratch;
        index.clear();
        index.extend(candidates.iter().enumerate().map(|(i, &w)| (w, i)));
        // candidate degree: neighbors strictly above k, or inside S
        cd.clear();
        cd.extend(candidates.iter().map(|&w| {
            self.adj[w as usize]
                .iter()
                .filter(|&&x| self.core[x as usize] > k || index.contains_key(&x))
                .count() as u32
        }));
        evicted.clear();
        evicted.resize(candidates.len(), false);
        // evict until fixpoint: members that cannot sustain k+1
        queue.clear();
        queue.extend((0..candidates.len()).filter(|&i| cd[i] <= k));
        while let Some(i) = queue.pop() {
            if evicted[i] {
                continue;
            }
            evicted[i] = true;
            let w = candidates[i];
            for &x in &self.adj[w as usize] {
                if let Some(&j) = index.get(&x) {
                    if !evicted[j] {
                        cd[j] -= 1;
                        if cd[j] <= k {
                            queue.push(j);
                        }
                    }
                }
            }
        }
        for (i, &w) in candidates.iter().enumerate() {
            if !evicted[i] {
                self.core[w as usize] = k + 1;
            }
        }
        true
    }

    /// Delete an undirected edge; returns true if it existed.
    pub fn delete_edge(&mut self, u: VertexId, v: VertexId) -> bool {
        let (u, v) = (u.min(v), u.max(v));
        if !self.delete_edge_structural(u, v) {
            return false;
        }

        let (cu, cv) = (self.core[u as usize], self.core[v as usize]);
        let k = cu.min(cv);
        if k == 0 {
            return true;
        }
        let mut roots = [0 as VertexId; 2];
        let mut nr = 0usize;
        for w in [u, v] {
            if self.core[w as usize] == k {
                roots[nr] = w;
                nr += 1;
            }
        }
        subcore_into(&self.adj, &self.core, k, &roots[..nr], &mut self.scratch);
        if self.scratch.sub.is_empty() {
            return true;
        }
        let MaintScratch {
            sub: candidates,
            index,
            deg: mcd,
            flag: demoted,
            queue,
            ..
        } = &mut self.scratch;
        index.clear();
        index.extend(candidates.iter().enumerate().map(|(i, &w)| (w, i)));
        // max-core degree: neighbors with core >= k
        mcd.clear();
        mcd.extend(candidates.iter().map(|&w| {
            self.adj[w as usize]
                .iter()
                .filter(|&&x| self.core[x as usize] >= k)
                .count() as u32
        }));
        demoted.clear();
        demoted.resize(candidates.len(), false);
        queue.clear();
        queue.extend((0..candidates.len()).filter(|&i| mcd[i] < k));
        while let Some(i) = queue.pop() {
            if demoted[i] {
                continue;
            }
            demoted[i] = true;
            let w = candidates[i];
            self.core[w as usize] = k - 1;
            for &x in &self.adj[w as usize] {
                if let Some(&j) = index.get(&x) {
                    if !demoted[j] {
                        mcd[j] -= 1;
                        if mcd[j] < k {
                            queue.push(j);
                        }
                    }
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::examples;
    use crate::util::rng::Rng;

    fn check(dc: &DynamicCore, label: &str) {
        let expected = bz_coreness(&dc.snapshot());
        assert_eq!(dc.coreness(), expected.as_slice(), "{label}");
    }

    #[test]
    fn insert_into_g1_creates_three_core() {
        let mut dc = DynamicCore::new(&examples::g1());
        assert_eq!(dc.coreness(), &examples::g1_coreness()[..]);
        // closing (v2, v5) makes {v2..v5} a K4 -> coreness 3
        assert!(dc.insert_edge(2, 5));
        check(&dc, "after insert (2,5)");
        assert_eq!(dc.coreness()[2..6], [3, 3, 3, 3]);
        // duplicate insert is a no-op
        assert!(!dc.insert_edge(5, 2));
    }

    #[test]
    fn delete_from_clique_demotes() {
        let mut dc = DynamicCore::new(&examples::complete(5));
        assert!(dc.delete_edge(0, 1));
        check(&dc, "after delete (0,1)");
        // K5 minus an edge: everyone drops to 3
        assert_eq!(dc.coreness(), &[3, 3, 3, 3, 3]);
        assert!(!dc.delete_edge(0, 1));
    }

    #[test]
    fn grow_from_empty() {
        let mut dc = DynamicCore::with_vertices(4);
        dc.insert_edge(0, 1);
        dc.insert_edge(1, 2);
        dc.insert_edge(2, 0);
        check(&dc, "triangle");
        assert_eq!(dc.coreness(), &[2, 2, 2, 0]);
        dc.insert_edge(3, 0);
        check(&dc, "triangle+tail");
        assert_eq!(dc.coreness()[3], 1);
    }

    #[test]
    fn randomized_edit_script_matches_oracle() {
        let n = 60;
        let mut dc = DynamicCore::with_vertices(n);
        let mut rng = Rng::new(0xD15C0);
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for step in 0..400 {
            let insert = edges.is_empty() || rng.chance(0.65);
            if insert {
                let u = rng.below(n as u64) as u32;
                let v = rng.below(n as u64) as u32;
                if u != v && !dc.has_edge(u, v) {
                    dc.insert_edge(u, v);
                    edges.push((u.min(v), u.max(v)));
                }
            } else {
                let i = rng.below_usize(edges.len());
                let (u, v) = edges.swap_remove(i);
                dc.delete_edge(u, v);
            }
            if step % 25 == 0 {
                check(&dc, &format!("step {step}"));
            }
        }
        check(&dc, "final");
    }

    #[test]
    fn apply_batch_matches_oracle() {
        let mut dc = DynamicCore::new(&examples::g1());
        let changed = dc.apply_batch(&[
            EdgeEdit::Insert(2, 5),
            EdgeEdit::Delete(0, 5),
            EdgeEdit::Insert(2, 5), // duplicate: no-op
            EdgeEdit::Insert(1, 1), // self-loop: no-op
        ]);
        assert_eq!(changed, 2);
        check(&dc, "after batch");
    }

    #[test]
    fn structural_edits_plus_recompute_match_incremental() {
        let g = examples::g1();
        let mut inc = DynamicCore::new(&g);
        let mut rec = DynamicCore::new(&g);
        let edits = [
            EdgeEdit::Insert(2, 5),
            EdgeEdit::Insert(0, 1),
            EdgeEdit::Delete(3, 4),
        ];
        inc.apply_batch(&edits);
        for e in edits {
            let changed = match e {
                EdgeEdit::Insert(u, v) => rec.insert_edge_structural(u, v),
                EdgeEdit::Delete(u, v) => rec.delete_edge_structural(u, v),
            };
            assert!(changed);
        }
        rec.recompute_with(&crate::core::bz::Bz, 1);
        assert_eq!(inc.coreness(), rec.coreness());
        check(&inc, "incremental");
        check(&rec, "recomputed");
    }

    #[test]
    fn ensure_vertex_grows_with_zero_core() {
        let mut dc = DynamicCore::with_vertices(2);
        dc.ensure_vertex(5);
        assert_eq!(dc.num_vertices(), 6);
        assert_eq!(dc.coreness()[5], 0);
        assert_eq!(dc.num_edges(), 0);
        dc.insert_edge(0, 5);
        check(&dc, "edge to grown vertex");
        assert_eq!(dc.num_edges(), 1);
        // idempotent / non-shrinking
        dc.ensure_vertex(3);
        assert_eq!(dc.num_vertices(), 6);
    }

    #[test]
    fn hoisted_scratch_counts_reuses_across_a_batch() {
        let mut dc = DynamicCore::new(&examples::g1());
        let before = crate::engine::metrics::scratch_reuses();
        // three maintenance edits against one index: every edit after the
        // first finds the hoisted work queues warm
        dc.apply_batch(&[
            EdgeEdit::Insert(2, 5),
            EdgeEdit::Delete(2, 5),
            EdgeEdit::Insert(2, 5),
        ]);
        check(&dc, "after counted batch");
        assert!(
            crate::engine::metrics::scratch_reuses() >= before + 2,
            "warm-buffer edits must be counted as saved allocations"
        );
    }

    #[test]
    fn maintenance_matches_fresh_on_suite_graph() {
        let g = crate::graph::gen::barabasi_albert(300, 3, 5);
        let mut dc = DynamicCore::new(&g);
        // hammer one region
        let mut rng = Rng::new(7);
        for _ in 0..60 {
            let u = rng.below(50) as u32;
            let v = rng.below(300) as u32;
            if u != v {
                if dc.has_edge(u, v) {
                    dc.delete_edge(u, v);
                } else {
                    dc.insert_edge(u, v);
                }
            }
        }
        check(&dc, "ba after churn");
    }
}
