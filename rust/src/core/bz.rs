//! Batagelj–Zaversnik serial peel (the O(M) bucket-sort algorithm, paper
//! ref [33]) — the ground-truth oracle every parallel algorithm and every
//! bench run is validated against.

use super::traits::{DecompositionResult, Decomposer, Paradigm};
use crate::engine::metrics::MetricsSnapshot;
use crate::graph::CsrGraph;

/// Serial BZ decomposition.
#[derive(Clone, Copy, Debug, Default)]
pub struct Bz;

/// Plain-function form: coreness of every vertex in O(M).
pub fn bz_coreness(g: &CsrGraph) -> Vec<u32> {
    let n = g.num_vertices();
    if n == 0 {
        return Vec::new();
    }
    let mut deg: Vec<u32> = g.degrees();
    let max_deg = *deg.iter().max().unwrap() as usize;

    // bin[d] = start index of the block of vertices with degree d.
    let mut bin = vec![0usize; max_deg + 2];
    for &d in &deg {
        bin[d as usize + 1] += 1;
    }
    for d in 0..=max_deg {
        bin[d + 1] += bin[d];
    }
    // vert = vertices sorted by degree; pos[v] = index of v in vert.
    let mut vert = vec![0u32; n];
    let mut pos = vec![0usize; n];
    {
        let mut cursor = bin.clone();
        for v in 0..n {
            let d = deg[v] as usize;
            vert[cursor[d]] = v as u32;
            pos[v] = cursor[d];
            cursor[d] += 1;
        }
    }

    // Peel in ascending degree order, shifting neighbors to lower bins.
    let mut core = vec![0u32; n];
    for i in 0..n {
        let v = vert[i];
        core[v as usize] = deg[v as usize];
        for &u in g.neighbors(v) {
            let u = u as usize;
            if deg[u] > deg[v as usize] {
                let du = deg[u] as usize;
                let pu = pos[u];
                // first vertex of u's current bin
                let pw = bin[du];
                let w = vert[pw];
                if u as u32 != w {
                    vert[pu] = w;
                    vert[pw] = u as u32;
                    pos[u] = pw;
                    pos[w as usize] = pu;
                }
                bin[du] += 1;
                deg[u] -= 1;
            }
        }
    }
    core
}

impl Decomposer for Bz {
    fn name(&self) -> &'static str {
        "BZ"
    }

    fn paradigm(&self) -> Paradigm {
        Paradigm::Serial
    }

    fn decompose_with(&self, g: &CsrGraph, _threads: usize, _metrics: bool) -> DecompositionResult {
        DecompositionResult {
            core: bz_coreness(g),
            iterations: 1,
            launches: 0,
            metrics: MetricsSnapshot::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::examples;

    #[test]
    fn g1_matches_paper() {
        assert_eq!(bz_coreness(&examples::g1()), examples::g1_coreness());
    }

    #[test]
    fn complete_graph() {
        let g = examples::complete(8);
        assert_eq!(bz_coreness(&g), vec![7; 8]);
    }

    #[test]
    fn path_is_one_core() {
        assert_eq!(bz_coreness(&examples::path(10)), vec![1; 10]);
    }

    #[test]
    fn cycle_is_two_core() {
        assert_eq!(bz_coreness(&examples::cycle(9)), vec![2; 9]);
    }

    #[test]
    fn star_and_isolated() {
        let g = examples::star(5);
        assert_eq!(bz_coreness(&g), vec![1; 6]);
        let g = crate::graph::GraphBuilder::new(3).build("iso");
        assert_eq!(bz_coreness(&g), vec![0; 3]);
    }

    #[test]
    fn empty_graph() {
        let g = crate::graph::CsrGraph::from_parts(vec![0], vec![], "e");
        assert_eq!(bz_coreness(&g), Vec::<u32>::new());
    }

    #[test]
    fn clique_chain_exact() {
        let (g, expected) = crate::graph::gen::nested_cliques(4, 3, 4);
        assert_eq!(bz_coreness(&g), expected);
    }

    #[test]
    fn coreness_le_degree() {
        let g = crate::graph::gen::erdos_renyi(500, 2500, 42);
        let core = bz_coreness(&g);
        for v in 0..g.num_vertices() {
            assert!(core[v] <= g.degree(v as u32));
        }
    }
}
