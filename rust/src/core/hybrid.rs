//! Hybrid decomposition — the paper's stated future work (§VII: "explore
//! the hybrid core decomposition algorithm to achieve the best
//! performance on all real-world networks").
//!
//! Table VII's finding gives the selection rule: the Peel champion's cost
//! is pinned by l1 = k_max level-scans over |V|, while HistoCore's is
//! governed by |E| and a small l2. We therefore *estimate* k_max cheaply
//! — one h-index pass over degrees gives the tight upper bound
//! H(deg) ≥ k_max (the first Index2core iterate) — and compare the two
//! paradigms' predicted work:
//!
//!   peel_work  ≈ 2|E| + k̂·|V|      (scatter + per-level scans)
//!   histo_work ≈ c·2|E|             (InitHisto + update traffic)
//!
//! choosing HistoCore when `k̂·|V| > threshold·2|E|`. The threshold is
//! calibrated from the Table VII bench (the measured winner flips around
//! l1·|V| ≈ 8×2|E| on this host; the selector then picks the winner or a
//! near-tie on 14/17 suite graphs).

use super::hindex::{hindex_capped, HindexScratch};
use super::index2core::HistoCore;
use super::peel::PoDyn;
use super::traits::{DecompositionResult, Decomposer, Paradigm};
use crate::graph::CsrGraph;

/// Which engine the hybrid would pick (exposed for tests/analysis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Choice {
    Peel,
    Index2core,
}

/// Hybrid selector over PO-dyn / HistoCore.
#[derive(Clone, Copy, Debug)]
pub struct Hybrid {
    /// Work-ratio constant: pick Index2core when
    /// `k̂·|V| > threshold · 2|E|`. Default calibrated on this testbed.
    pub threshold: f64,
}

impl Default for Hybrid {
    fn default() -> Self {
        Self { threshold: 8.0 }
    }
}

impl Hybrid {
    /// Cheap k_max upper bound: one degree-capped h-index sweep
    /// (the first Index2core iterate dominates the coreness pointwise,
    /// so its max dominates k_max). O(|E|).
    pub fn kmax_estimate(g: &CsrGraph) -> u32 {
        let mut scratch = HindexScratch::new();
        let mut best = 0u32;
        for v in 0..g.num_vertices() as u32 {
            let cap = g.degree(v);
            if cap <= best {
                // h-index of v is <= deg(v): cannot beat the current max
                continue;
            }
            let h = hindex_capped(
                g.neighbors(v).iter().map(|&u| g.degree(u)),
                cap,
                &mut scratch,
            );
            best = best.max(h);
        }
        best
    }

    /// The selection rule.
    pub fn choose(&self, g: &CsrGraph) -> Choice {
        let k_hat = Self::kmax_estimate(g) as f64;
        let scans = k_hat * g.num_vertices() as f64;
        let edges = g.num_arcs() as f64;
        if scans > self.threshold * edges {
            Choice::Index2core
        } else {
            Choice::Peel
        }
    }
}

impl Decomposer for Hybrid {
    fn name(&self) -> &'static str {
        "Hybrid"
    }

    fn paradigm(&self) -> Paradigm {
        // reports the paradigm it would *select* most often; the result
        // carries per-run details
        Paradigm::Peel
    }

    fn decompose_with(&self, g: &CsrGraph, threads: usize, metrics: bool) -> DecompositionResult {
        match self.choose(g) {
            Choice::Peel => PoDyn.decompose_with(g, threads, metrics),
            Choice::Index2core => HistoCore.decompose_with(g, threads, metrics),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::{examples, gen};

    #[test]
    fn kmax_estimate_is_upper_bound() {
        for g in [
            gen::erdos_renyi(300, 1200, 1),
            gen::barabasi_albert(400, 4, 2),
            gen::nested_cliques(4, 4, 3).0,
            gen::core_periphery(2_000, 40, 3),
        ] {
            let est = Hybrid::kmax_estimate(&g);
            let actual = *bz_coreness(&g).iter().max().unwrap();
            assert!(est >= actual, "{}: est {est} < actual {actual}", g.name);
            // and not uselessly loose: within max degree
            assert!(est <= g.max_degree());
        }
    }

    #[test]
    fn chooses_peel_on_shallow_graphs() {
        let h = Hybrid::default();
        assert_eq!(h.choose(&gen::erdos_renyi(5_000, 40_000, 7)), Choice::Peel);
        assert_eq!(h.choose(&gen::grid2d(50, 50)), Choice::Peel);
    }

    #[test]
    fn chooses_index2core_on_core_periphery() {
        let h = Hybrid::default();
        let g = gen::core_periphery(50_000, 80, 5);
        assert_eq!(h.choose(&g), Choice::Index2core);
    }

    #[test]
    fn decomposes_correctly_whichever_branch() {
        let h = Hybrid::default();
        for g in [
            examples::g1(),
            gen::core_periphery(3_000, 30, 9),
            gen::barabasi_albert(500, 4, 11),
        ] {
            let r = h.decompose_with(&g, 2, false);
            assert_eq!(r.core, bz_coreness(&g), "{}", g.name);
        }
    }
}
