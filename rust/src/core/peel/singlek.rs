//! Sort-free single-k extraction (Xiang-style, "Simple linear algorithms
//! for mining graph cores", PAPERS.md).
//!
//! A `MEMBERS k` / k-core-size query needs one level set, not the whole
//! decomposition: delete vertices with degree `< k`, cascade the degree
//! drops to a fixpoint, and what survives *is* the k-core — `O(n + m)`
//! with no bucket sort and no per-level machinery. The extractor runs
//! against any [`KCoreSource`]; two sources matter in practice:
//!
//! * [`CsrGraph`] — the committed, immutable structure;
//! * [`LiveView`] — the writer's adjacency plus the *pending, uncommitted*
//!   edit overlay, which is how the serving layer answers `MEMBERS k`
//!   mid-batch without waiting for (or paying) a flush. The overlay
//!   coalesces last-wins on canonical endpoints — the same rule
//!   `service::batch::coalesce` applies at flush time, so a mid-batch
//!   answer and the post-flush answer agree by construction.

use crate::core::maintenance::{DynamicCore, EdgeEdit};
use crate::graph::{CsrGraph, VertexId};
use std::collections::{HashMap, HashSet};

/// Adjacency access for the single-k extractor — implemented by the CSR
/// snapshot and by the live pending-edit overlay.
pub trait KCoreSource {
    fn num_vertices(&self) -> usize;

    /// Visit every neighbor of `v` exactly once.
    fn for_each_neighbor(&self, v: usize, f: &mut dyn FnMut(VertexId));

    /// Degree of `v`; the default counts neighbors.
    fn degree(&self, v: usize) -> u32 {
        let mut d = 0u32;
        self.for_each_neighbor(v, &mut |_| d += 1);
        d
    }
}

impl KCoreSource for CsrGraph {
    fn num_vertices(&self) -> usize {
        CsrGraph::num_vertices(self)
    }

    fn for_each_neighbor(&self, v: usize, f: &mut dyn FnMut(VertexId)) {
        for &u in self.neighbors(v as VertexId) {
            f(u);
        }
    }

    fn degree(&self, v: usize) -> u32 {
        self.neighbors(v as VertexId).len() as u32
    }
}

/// Result of one extraction: the k-core as a presence bitmap, with the
/// size tracked during the peel so counting callers never materialise a
/// member list.
#[derive(Clone, Debug)]
pub struct KCoreSet {
    k: u32,
    present: Vec<bool>,
    size: usize,
}

impl KCoreSet {
    pub fn k(&self) -> u32 {
        self.k
    }

    /// |k-core| — free, no materialisation.
    pub fn size(&self) -> usize {
        self.size
    }

    pub fn is_empty(&self) -> bool {
        self.size == 0
    }

    pub fn contains(&self, v: VertexId) -> bool {
        self.present.get(v as usize).copied().unwrap_or(false)
    }

    /// Members ascending (allocates once, exactly `size` slots).
    pub fn members(&self) -> Vec<VertexId> {
        let mut out = Vec::with_capacity(self.size);
        out.extend(
            (0..self.present.len() as VertexId).filter(|&v| self.present[v as usize]),
        );
        out
    }

    /// First `cap` members ascending — the reply-listing path, which
    /// never needs more than the protocol's cap.
    pub fn members_capped(&self, cap: usize) -> Vec<VertexId> {
        (0..self.present.len() as VertexId)
            .filter(|&v| self.present[v as usize])
            .take(cap)
            .collect()
    }
}

/// Extract the k-core of `src`: delete every vertex with degree `< k`,
/// cascading to the fixpoint. `k = 0` is the whole vertex set (isolated
/// vertices included); `k` above the degeneracy empties out.
pub fn single_k<S: KCoreSource + ?Sized>(src: &S, k: u32) -> KCoreSet {
    let n = src.num_vertices();
    let mut present = vec![true; n];
    let mut size = n;
    if k == 0 || n == 0 {
        return KCoreSet { k, present, size };
    }
    let mut deg: Vec<u32> = (0..n).map(|v| src.degree(v)).collect();
    let mut queue: Vec<VertexId> =
        (0..n as VertexId).filter(|&v| deg[v as usize] < k).collect();
    for &v in &queue {
        present[v as usize] = false;
    }
    size -= queue.len();
    while let Some(v) = queue.pop() {
        src.for_each_neighbor(v as usize, &mut |u| {
            let u = u as usize;
            if present[u] {
                deg[u] -= 1;
                if deg[u] < k {
                    present[u] = false;
                    size -= 1;
                    queue.push(u as VertexId);
                }
            }
        });
    }
    KCoreSet { k, present, size }
}

/// Counting variant: |k-core| without touching a member list.
pub fn single_k_size<S: KCoreSource + ?Sized>(src: &S, k: u32) -> usize {
    single_k(src, k).size()
}

/// The writer's adjacency with the pending edit queue layered on top —
/// the structure a flush *would* commit, viewed without committing it.
///
/// Inserts not present in the base adjacency land in a per-vertex extra
/// list (growing the vertex set when an edit names an unseen id, exactly
/// like `DynamicCore::ensure_vertex` at flush); deletes of present edges
/// land in a removed set consulted per arc. Edits that no-op against the
/// base (duplicate inserts, deletes of absent edges, self-loops) are
/// dropped, mirroring the flush path.
pub struct LiveView<'a> {
    dc: &'a DynamicCore,
    extra: HashMap<VertexId, Vec<VertexId>>,
    removed: HashSet<(VertexId, VertexId)>,
    n: usize,
}

impl<'a> LiveView<'a> {
    pub fn new(dc: &'a DynamicCore, pending: &[EdgeEdit]) -> Self {
        let base_n = dc.num_vertices();
        // last-wins per canonical endpoint pair (= service::batch::coalesce)
        let mut last: HashMap<(VertexId, VertexId), bool> = HashMap::new();
        for e in pending {
            let (a, b) = e.endpoints();
            if a == b {
                continue;
            }
            last.insert((a, b), e.is_insert());
        }
        let mut n = base_n;
        let mut extra: HashMap<VertexId, Vec<VertexId>> = HashMap::new();
        let mut removed: HashSet<(VertexId, VertexId)> = HashSet::new();
        for ((a, b), insert) in last {
            let exists = (b as usize) < base_n && dc.has_edge(a, b);
            if insert && !exists {
                extra.entry(a).or_default().push(b);
                extra.entry(b).or_default().push(a);
                n = n.max(b as usize + 1);
            } else if !insert && exists {
                removed.insert((a, b));
            }
        }
        LiveView {
            dc,
            extra,
            removed,
            n,
        }
    }
}

impl KCoreSource for LiveView<'_> {
    fn num_vertices(&self) -> usize {
        self.n
    }

    fn for_each_neighbor(&self, v: usize, f: &mut dyn FnMut(VertexId)) {
        let vv = v as VertexId;
        if v < self.dc.num_vertices() {
            for &u in self.dc.neighbors(vv) {
                if self.removed.is_empty() || !self.removed.contains(&(vv.min(u), vv.max(u)))
                {
                    f(u);
                }
            }
        }
        if let Some(ex) = self.extra.get(&vv) {
            for &u in ex {
                f(u);
            }
        }
    }
}

/// The `MEMBERS k` fast path: the k-core of the live graph (writer
/// adjacency + pending edits), one `O(n + m)` pass, no decomposition.
pub fn live_kcore(dc: &DynamicCore, pending: &[EdgeEdit], k: u32) -> KCoreSet {
    single_k(&LiveView::new(dc, pending), k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::{examples, gen, GraphBuilder};

    /// Oracle: members from a full decomposition.
    fn bz_members(g: &CsrGraph, k: u32) -> Vec<VertexId> {
        bz_coreness(g)
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c >= k)
            .map(|(v, _)| v as VertexId)
            .collect()
    }

    #[test]
    fn matches_full_decomposition_across_k() {
        let g = gen::barabasi_albert(400, 4, 7);
        let kmax = *bz_coreness(&g).iter().max().unwrap();
        for k in 0..=kmax + 2 {
            let s = single_k(&g, k);
            assert_eq!(s.members(), bz_members(&g, k), "k={k}");
            assert_eq!(s.size(), bz_members(&g, k).len(), "k={k}");
            assert_eq!(single_k_size(&g, k), s.size(), "k={k}");
        }
    }

    #[test]
    fn k_zero_includes_isolated_vertices() {
        let mut b = GraphBuilder::new(5);
        b.add_edge(0, 1);
        let g = b.build("mostly-isolated");
        let s = single_k(&g, 0);
        assert_eq!(s.members(), vec![0, 1, 2, 3, 4]);
        assert_eq!(single_k(&g, 1).members(), vec![0, 1]);
        assert!(single_k(&g, 2).is_empty());
    }

    #[test]
    fn capped_listing_is_a_prefix() {
        let g = examples::g1();
        let s = single_k(&g, 2);
        assert_eq!(s.members(), vec![2, 3, 4, 5]);
        assert_eq!(s.members_capped(2), vec![2, 3]);
        assert!(s.contains(3) && !s.contains(0));
    }

    #[test]
    fn live_overlay_matches_flushed_graph() {
        let g = examples::g1();
        let dc = DynamicCore::new(&g);
        let pending = [
            EdgeEdit::Insert(2, 5),  // closes K4 over {2,3,4,5}
            EdgeEdit::Delete(0, 5),  // prunes a 1-core arc
            EdgeEdit::Insert(2, 5),  // duplicate: no-op
            EdgeEdit::Insert(1, 1),  // self-loop: no-op
            EdgeEdit::Insert(7, 8),  // grows the vertex set
            EdgeEdit::Delete(8, 9),  // absent edge: no-op (but grows ids seen)
        ];
        let mut flushed = DynamicCore::new(&g);
        flushed.ensure_vertex(8);
        flushed.apply_batch(&pending);
        let fg = flushed.snapshot();
        let kmax = *bz_coreness(&fg).iter().max().unwrap();
        for k in 0..=kmax + 1 {
            let live = live_kcore(&dc, &pending, k);
            let want = bz_members(&fg, k);
            assert_eq!(live.members(), want, "k={k}");
            assert_eq!(live.size(), want.len(), "k={k}");
        }
    }

    #[test]
    fn live_overlay_insert_then_delete_coalesces_last_wins() {
        let g = examples::g1();
        let dc = DynamicCore::new(&g);
        // inserted then deleted before the flush: must not appear
        let pending = [EdgeEdit::Insert(2, 5), EdgeEdit::Delete(2, 5)];
        let live = live_kcore(&dc, &pending, 2);
        assert_eq!(live.members(), bz_members(&g, 2));
        // deleted then re-inserted: must still appear
        let pending = [EdgeEdit::Delete(3, 4), EdgeEdit::Insert(3, 4)];
        let live = live_kcore(&dc, &pending, 2);
        assert_eq!(live.members(), bz_members(&g, 2));
    }

    #[test]
    fn empty_and_oversized_k() {
        let g = GraphBuilder::new(0).build("empty");
        assert!(single_k(&g, 0).members().is_empty());
        assert!(single_k(&g, 3).is_empty());
        let g = examples::complete(4);
        assert!(single_k(&g, 4).is_empty(), "k above degeneracy empties");
        assert_eq!(single_k(&g, 3).size(), 4);
    }
}
