//! PeelOne (Algorithm 4) — the paper's proposed Peel algorithm.
//!
//! Three optimisations over GPP (§III.C):
//! 1. **Single property array.** `core[]` is initialised to the degree and
//!    doubles as the residual degree; by Corollary 1 residual vertices
//!    always satisfy `core[v] >= k`, so the frontier test is the single
//!    equality `core[v] == k` and the `rem` flag disappears (removed
//!    vertices have `core < k`, asserted vertices exactly `k`).
//! 2. **Assertion method.** Degree updates use `atomicSub_{>=k}`
//!    ([`atomic_sub_floor`]): an under-core vertex is clamped *at* `k`
//!    (its coreness, Theorem 1) instead of being driven below and patched
//!    back — saving the `2(n−m)` extra atomics of Fig. 4.
//! 3. *(in PO-dyn)* **Dynamic frontiers.** This variant is the static
//!    form: every round re-scans the vertex set for `core == k` (that is
//!    what l1 ≈ Σ per-level rounds counts, Table V's left column);
//!    [`super::PoDyn`] replaces the rescans with the live work-list fed
//!    by the unique `Written(k)` floor-hit signal.

use crate::core::traits::{DecompositionResult, Decomposer, Paradigm};
use crate::engine::atomics::{atomic_sub_floor, AtomicCoreArray, SubFloor};
use crate::engine::frontier::WorkList;
use crate::engine::metrics::Metrics;
use crate::engine::spmd::run_spmd;
use crate::graph::CsrGraph;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Algorithm 4 with per-round static frontiers.
#[derive(Clone, Copy, Debug, Default)]
pub struct PeelOne;

impl Decomposer for PeelOne {
    fn name(&self) -> &'static str {
        "PeelOne"
    }

    fn paradigm(&self) -> Paradigm {
        Paradigm::Peel
    }

    fn decompose_with(&self, g: &CsrGraph, threads: usize, metrics_on: bool) -> DecompositionResult {
        let n = g.num_vertices();
        let metrics = Metrics::new(threads, metrics_on);
        if n == 0 {
            return DecompositionResult {
                core: vec![],
                iterations: 0,
                launches: 0,
                metrics: metrics.snapshot(),
            };
        }

        // core[] doubles as residual degree (Alg 4 line 1).
        let core = AtomicCoreArray::from_vec(g.degrees());
        let frontier = WorkList::new(n);
        // Scan-dedup stamp: a processed frontier vertex keeps core == k
        // (its coreness) and must not re-enter later rounds of the level.
        let queued: Vec<std::sync::atomic::AtomicBool> =
            (0..n).map(|_| std::sync::atomic::AtomicBool::new(false)).collect();
        let remaining = AtomicUsize::new(n);
        let iterations = AtomicUsize::new(0);
        let round_end_shared = AtomicUsize::new(0);

        let launches = run_spmd(threads, |ctx| {
            let mv = metrics.view(ctx.tid);

            // Level 0: isolated vertices are already converged (core 0).
            let isolated = ctx.static_chunk(n).filter(|&v| core.load(v) == 0).count();
            if isolated > 0 {
                remaining.fetch_sub(isolated, Ordering::AcqRel);
            }
            ctx.barrier();

            let mut k = 0u32;
            loop {
                if remaining.load(Ordering::Acquire) == 0 {
                    break;
                }
                k += 1;

                // ---- scan/scatter rounds at level k (Alg 4 faithfully:
                // the *static* variant re-scans the whole vertex set each
                // round; detecting new frontiers without a rescan is
                // exactly the dynamic-frontier upgrade of PO-dyn). The
                // `queued` stamp keeps processed frontier vertices (whose
                // core stays == k, their coreness) out of later scans.
                // Round bounds are published by thread 0 between barriers
                // so all workers agree on the slice.
                let mut round_start = 0usize;
                loop {
                    // scan kernel: V_f = {v : core[v] == k, not yet queued}.
                    // Predicate order matters on every architecture: the
                    // 1-byte queued stream short-circuits processed
                    // vertices (whose core stays == k forever) before the
                    // 4-byte core load, and the RMW swap runs at most once
                    // per vertex — mirroring how GPP's rem[] flag guards
                    // its two-array test.
                    let range = ctx.static_chunk(n);
                    let lo = range.start;
                    for (i, q) in queued[range].iter().enumerate() {
                        // slice iteration: bounds checks and Vec metadata
                        // loads hoisted out of the 7M-iteration hot loop
                        if !q.load(Ordering::Relaxed) {
                            let v = lo + i;
                            if core.load(v) == k && !q.swap(true, Ordering::Relaxed) {
                                frontier.push(v as u32);
                                mv.frontier_pushes(1);
                            }
                        }
                    }
                    ctx.launch_boundary();
                    if ctx.tid == 0 {
                        round_end_shared.store(frontier.pushed(), Ordering::Relaxed);
                    }
                    ctx.barrier();
                    let round_end = round_end_shared.load(Ordering::Relaxed);
                    if round_start == round_end {
                        break;
                    }
                    // scatter kernel over this round's slice
                    let len = round_end - round_start;
                    let per = len.div_ceil(ctx.num_threads);
                    let lo = round_start + (ctx.tid * per).min(len);
                    let hi = round_start + ((ctx.tid + 1) * per).min(len);
                    for i in lo..hi {
                        let v = frontier.get(i);
                        for &u in g.neighbors(v) {
                            mv.edge_accesses(1);
                            let u = u as usize;
                            if core.load(u) > k {
                                // assertion method: clamp at the floor k
                                let _ = atomic_sub_floor(core.cell(u), k, &mv);
                            }
                        }
                    }
                    ctx.launch_boundary();
                    if ctx.tid == 0 {
                        iterations.fetch_add(1, Ordering::Relaxed);
                    }
                    round_start = round_end;
                }

                // Level done: everything queued this level had coreness k.
                ctx.barrier();
                if ctx.tid == 0 {
                    remaining.fetch_sub(frontier.pushed(), Ordering::AcqRel);
                    frontier.reset();
                }
                ctx.barrier();
            }
        });

        DecompositionResult {
            core: core.to_vec(),
            iterations: iterations.load(Ordering::Relaxed),
            launches,
            metrics: metrics.snapshot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::{examples, gen};

    #[test]
    fn g1_matches_paper_walkthrough() {
        // Fig. 5: frontiers {v0,v1} at k=1, {v2,v4} at k=2 with v3,v5
        // asserted under-core — final coreness [1,1,2,2,2,2].
        let r = PeelOne.decompose_with(&examples::g1(), 2, true);
        assert_eq!(r.core, examples::g1_coreness());
        // assertion method: no atomicAdd corrections ever
        assert_eq!(r.metrics.atomic_adds, 0);
    }

    #[test]
    fn matches_bz_on_random_graphs() {
        for seed in 0..3 {
            let g = gen::erdos_renyi(300, 1200, seed);
            let r = PeelOne.decompose_with(&g, 4, false);
            assert_eq!(r.core, bz_coreness(&g), "seed={seed}");
        }
    }

    #[test]
    fn matches_bz_on_powerlaw_and_planted() {
        let g = gen::barabasi_albert(800, 3, 5);
        assert_eq!(PeelOne.decompose_with(&g, 4, false).core, bz_coreness(&g));
        let g = gen::planted_core(1000, 3000, &[(200, 12), (50, 25)], 7);
        assert_eq!(PeelOne.decompose_with(&g, 4, false).core, bz_coreness(&g));
    }

    #[test]
    fn clique_chain_exact() {
        let (g, expected) = gen::nested_cliques(4, 3, 4);
        assert_eq!(PeelOne.decompose_with(&g, 4, false).core, expected);
    }

    #[test]
    fn single_thread_works() {
        let g = gen::rmat(8, 6, 0.57, 0.19, 0.19, 2);
        assert_eq!(PeelOne.decompose_with(&g, 1, false).core, bz_coreness(&g));
    }

    #[test]
    fn isolated_vertices_terminate() {
        let mut b = crate::graph::GraphBuilder::new(5);
        b.add_edge(0, 1);
        let g = b.build("mostly-isolated");
        let r = PeelOne.decompose_with(&g, 2, false);
        assert_eq!(r.core, vec![1, 1, 0, 0, 0]);
    }

    #[test]
    fn fewer_atomics_than_gpp() {
        // The Fig. 4 claim: assertion eliminates under-core atomics.
        let g = gen::barabasi_albert(2000, 5, 11);
        let po = PeelOne.decompose_with(&g, 4, true);
        let gpp = crate::core::peel::Gpp.decompose_with(&g, 4, true);
        assert!(
            po.metrics.total_atomics() <= gpp.metrics.total_atomics(),
            "PeelOne {} vs GPP {}",
            po.metrics.total_atomics(),
            gpp.metrics.total_atomics()
        );
    }
}
