//! PP-dyn — the SOTA GPU Peel baseline [21]: dynamic frontier queue like
//! PO-dyn, but *without* the assertion method. Under-core vertices are
//! driven below `k` by plain `atomicSub` and patched back with an extra
//! `atomicAdd` (the Fig. 4a workflow: `2n − m` atomics where the assertion
//! needs `n − m`), and a separate `rem` flag plus a second property array
//! are retained. The benches compare its measured atomic counts against
//! PO-dyn to regenerate the Fig. 4 claim.

use crate::core::traits::{DecompositionResult, Decomposer, Paradigm};
use crate::engine::atomics::{atomic_add_one, atomic_sub_one, AtomicCoreArray};
use crate::engine::frontier::WorkList;
use crate::engine::metrics::Metrics;
use crate::engine::spmd::run_spmd;
use crate::graph::CsrGraph;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Dynamic-frontier Peel with atomicAdd under-core correction [21].
#[derive(Clone, Copy, Debug, Default)]
pub struct PpDyn;

impl Decomposer for PpDyn {
    fn name(&self) -> &'static str {
        "PP-dyn"
    }

    fn paradigm(&self) -> Paradigm {
        Paradigm::Peel
    }

    fn decompose_with(&self, g: &CsrGraph, threads: usize, metrics_on: bool) -> DecompositionResult {
        let n = g.num_vertices();
        let metrics = Metrics::new(threads, metrics_on);
        if n == 0 {
            return DecompositionResult {
                core: vec![],
                iterations: 0,
                launches: 0,
                metrics: metrics.snapshot(),
            };
        }

        let deg = AtomicCoreArray::from_vec(g.degrees());
        let core = AtomicCoreArray::zeros(n);
        let rem: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
        let frontier = WorkList::new(n);
        let remaining = AtomicUsize::new(n);
        let iterations = AtomicUsize::new(0);
        // Wrap detection for transient below-zero excursions of `deg`.
        let wrap_threshold = u32::MAX / 2;

        let launches = run_spmd(threads, |ctx| {
            let mv = metrics.view(ctx.tid);
            let mut k = 0u32;
            loop {
                if remaining.load(Ordering::Acquire) == 0 {
                    break;
                }

                // ---- scan: seed {!rem && deg <= k} ----
                // (rem load first: removed vertices keep deg <= k forever,
                // an unguarded swap would RMW all of them every level)
                for v in ctx.static_chunk(n) {
                    if !rem[v].load(Ordering::Relaxed)
                        && deg.load(v) <= k
                        && !rem[v].swap(true, Ordering::Relaxed)
                    {
                        core.store(v, k);
                        frontier.push(v as u32);
                        mv.frontier_pushes(1);
                    }
                }
                ctx.launch_boundary();

                // ---- drain with atomicSub + atomicAdd correction ----
                let process = |v: u32, frontier: &crate::engine::frontier::WorkList| {
                    for &u in g.neighbors(v) {
                        mv.edge_accesses(1);
                        let u = u as usize;
                        if rem[u].load(Ordering::Relaxed) {
                            continue;
                        }
                        let nv = atomic_sub_one(deg.cell(u), &mv);
                        if nv == k {
                            // first arrival at k: this thread removes u
                            if !rem[u].swap(true, Ordering::Relaxed) {
                                core.store(u, k);
                                frontier.push(u as u32);
                                mv.frontier_pushes(1);
                            }
                        } else if nv > wrap_threshold || nv < k {
                            // under-core excursion: patch back (the extra
                            // atomic the assertion method eliminates)
                            atomic_add_one(deg.cell(u), &mv);
                        }
                    }
                };
                if ctx.num_threads == 1 {
                    frontier.drain_seq(process);
                } else {
                    frontier.drain(process);
                }
                ctx.launch_boundary();

                if ctx.tid == 0 {
                    iterations.fetch_add(1, Ordering::Relaxed);
                    remaining.fetch_sub(frontier.pushed(), Ordering::AcqRel);
                    frontier.reset();
                }
                ctx.barrier();
                k += 1;
            }
        });

        DecompositionResult {
            core: core.to_vec(),
            iterations: iterations.load(Ordering::Relaxed),
            launches,
            metrics: metrics.snapshot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::{examples, gen};

    #[test]
    fn g1_matches_paper() {
        let r = PpDyn.decompose_with(&examples::g1(), 2, false);
        assert_eq!(r.core, examples::g1_coreness());
    }

    #[test]
    fn matches_bz_on_random_graphs() {
        for seed in 0..4 {
            let g = gen::erdos_renyi(400, 1600, seed);
            assert_eq!(PpDyn.decompose_with(&g, 4, false).core, bz_coreness(&g), "seed={seed}");
        }
    }

    #[test]
    fn matches_bz_on_powerlaw() {
        let g = gen::barabasi_albert(1000, 4, 3);
        assert_eq!(PpDyn.decompose_with(&g, 8, false).core, bz_coreness(&g));
    }

    #[test]
    fn isolated_vertices_get_core_zero() {
        let mut b = crate::graph::GraphBuilder::new(5);
        b.add_edge(0, 1);
        let g = b.build("iso");
        assert_eq!(PpDyn.decompose_with(&g, 2, false).core, vec![1, 1, 0, 0, 0]);
    }

    #[test]
    fn single_thread_works() {
        let g = gen::caveman(20, 6, 7);
        assert_eq!(PpDyn.decompose_with(&g, 1, false).core, bz_coreness(&g));
    }

    #[test]
    fn uses_atomic_adds_where_podyn_does_not() {
        // Fig. 4: PP-dyn pays correction atomicAdds on under-core vertices;
        // PO-dyn's assertion removes them. Use a clique chain, which is
        // rich in under-core events.
        let (g, _) = gen::nested_cliques(4, 6, 6);
        let pp = PpDyn.decompose_with(&g, 8, true);
        let po = crate::core::peel::PoDyn.decompose_with(&g, 8, true);
        assert_eq!(pp.core, po.core);
        assert_eq!(po.metrics.atomic_adds, 0);
        assert!(
            pp.metrics.total_atomics() >= po.metrics.total_atomics(),
            "PP-dyn {} vs PO-dyn {}",
            pp.metrics.total_atomics(),
            po.metrics.total_atomics()
        );
    }
}
