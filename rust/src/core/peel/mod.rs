//! Peel-paradigm algorithms (bottom-up removal, §II-A Algorithm 1):
//! the GPP baseline, the proposed PeelOne (assertion method), the
//! dynamic-frontier SOTA baseline PP-dyn, the proposed PO-dyn, the
//! hierarchical-bucket BucketPeel (theory-practice recompute kernel),
//! and the sort-free single-k extractor behind the `MEMBERS` fast path.

pub mod bucket;
pub mod gpp;
pub mod peelone;
pub mod podyn;
pub mod ppdyn;
pub mod singlek;

pub use bucket::{bucket_peel_into, BucketPeel, BucketScratch};
pub use gpp::Gpp;
pub use peelone::PeelOne;
pub use podyn::PoDyn;
pub use ppdyn::PpDyn;
pub use singlek::{live_kcore, single_k, single_k_size, KCoreSet, KCoreSource, LiveView};
