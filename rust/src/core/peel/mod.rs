//! Peel-paradigm algorithms (bottom-up removal, §II-A Algorithm 1):
//! the GPP baseline, the proposed PeelOne (assertion method), the
//! dynamic-frontier SOTA baseline PP-dyn, and the proposed PO-dyn.

pub mod gpp;
pub mod peelone;
pub mod podyn;
pub mod ppdyn;

pub use gpp::Gpp;
pub use peelone::PeelOne;
pub use podyn::PoDyn;
pub use ppdyn::PpDyn;
