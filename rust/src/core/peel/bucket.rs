//! BucketPeel — hierarchical-bucket parallel peel (theory-practice style).
//!
//! [`super::PeelOne`] re-scans the *whole* vertex set once per round, so its
//! scan cost is `O(n · Σ per-level rounds)` — the term that dominates the
//! flush-stage recompute on high-`k_max` graphs. Following the
//! hierarchical bucketing of Liu & Dong ("Parallel k-Core Decomposition:
//! Theory and Practice", PAPERS.md), this kernel groups levels into
//! log-spaced ranges `[k_lo, k_hi)` with `k_hi = max(k_lo+1, 2·k_lo)` and
//! pays **one** full scan per bucket to collect a local member list; every
//! round inside the bucket scans only that list. Scan cost drops to
//! `O(n · log k_max + Σ bucket work)`.
//!
//! Correctness of the once-per-run `binned` stamp rests on the residual
//! invariant `core[v] >= coreness(v)`: a vertex's residual enters
//! `[k_lo, k_hi)` exactly when its coreness lies there, so it belongs to
//! exactly one bucket, ever. Vertices whose residual is still `>= k_hi` at
//! collection time are admitted *dynamically* by the scatter kernel — the
//! assertion decrement ([`atomic_sub_floor`]) moves residuals in unit
//! steps, so the first write below `k_hi` is never skipped. If the
//! collection scan finds nothing, no remaining vertex has coreness below
//! `k_hi` (a sub-`k_hi` min-degree vertex would show a sub-`k_hi`
//! residual), so the whole bucket is skipped in one scan.
//!
//! PeelOne's other traits are retained: the single `core[]` property array
//! doubling as residual degree, and the assertion method (under-core
//! vertices clamped *at* their coreness, zero atomicAdd corrections).
//! Round scans and scatters are work-stolen via [`SpmdCtx::dynamic_chunks`]
//! rather than statically split — member lists are small and skewed, so a
//! static split would leave workers idle behind one hub-heavy chunk.

use crate::core::traits::{DecompositionResult, Decomposer, Paradigm};
use crate::engine::atomics::{atomic_sub_floor, AtomicCoreArray, SubFloor};
use crate::engine::frontier::WorkList;
use crate::engine::metrics::Metrics;
use crate::engine::spmd::run_spmd;
use crate::graph::CsrGraph;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Hierarchical-bucket peel with per-bucket local frontiers.
#[derive(Clone, Copy, Debug, Default)]
pub struct BucketPeel;

impl Decomposer for BucketPeel {
    fn name(&self) -> &'static str {
        "BucketPeel"
    }

    fn paradigm(&self) -> Paradigm {
        Paradigm::Peel
    }

    fn decompose_with(&self, g: &CsrGraph, threads: usize, metrics_on: bool) -> DecompositionResult {
        let n = g.num_vertices();
        let metrics = Metrics::new(threads, metrics_on);
        if n == 0 {
            return DecompositionResult {
                core: vec![],
                iterations: 0,
                launches: 0,
                metrics: metrics.snapshot(),
            };
        }

        let mut scratch = BucketScratch::with_capacity(n);
        let (iterations, launches) =
            bucket_peel_into(g, threads, &metrics, &mut scratch);
        DecompositionResult {
            core: scratch.core.to_vec(),
            iterations,
            launches,
            metrics: metrics.snapshot(),
        }
    }
}

/// Reusable working set of one [`BucketPeel`] run: the residual/core
/// array, the bucket member list, the per-level frontier, and the two
/// dedup stamps. Holding one of these per index lets every flush-time
/// recompute skip five `O(n)` allocations (the tentpole's scratch-reuse
/// requirement); [`BucketScratch::ensure`] re-initialises in place.
#[derive(Debug)]
pub struct BucketScratch {
    core: AtomicCoreArray,
    members: WorkList,
    frontier: WorkList,
    /// Peeled stamp: set once when a vertex enters a level frontier.
    queued: Vec<AtomicBool>,
    /// Bucketed stamp: set once when a vertex enters a member list.
    binned: Vec<AtomicBool>,
}

impl BucketScratch {
    pub fn with_capacity(n: usize) -> Self {
        let mut s = BucketScratch {
            core: AtomicCoreArray::zeros(0),
            members: WorkList::new(0),
            frontier: WorkList::new(0),
            queued: vec![],
            binned: vec![],
        };
        s.ensure(n);
        s
    }

    /// Current vertex capacity.
    pub fn capacity(&self) -> usize {
        self.queued.len()
    }

    /// Grow (never shrink) to hold `n` vertices. Returns `true` when the
    /// existing buffers were large enough and got reused in place.
    pub fn ensure(&mut self, n: usize) -> bool {
        if n <= self.capacity() && self.frontier.capacity() >= n {
            return true;
        }
        self.core = AtomicCoreArray::zeros(n);
        self.members = WorkList::new(n);
        self.frontier = WorkList::new(n);
        self.queued = (0..n).map(|_| AtomicBool::new(false)).collect();
        self.binned = (0..n).map(|_| AtomicBool::new(false)).collect();
        false
    }

    /// Copy the first `n` computed coreness values into `out`, reusing
    /// its allocation (the scratch may be larger than the last run's
    /// graph, so callers name the prefix explicitly).
    pub fn copy_core_into(&self, n: usize, out: &mut Vec<u32>) {
        debug_assert!(n <= self.capacity());
        out.clear();
        out.extend((0..n).map(|v| self.core.load(v)));
    }

    /// Reset the first `n` slots for a fresh run (single-threaded; the
    /// stamps are once-per-run, so this is the only place they clear).
    fn reset(&mut self, degrees: &[u32]) {
        let n = degrees.len();
        debug_assert!(n <= self.capacity());
        for (v, &d) in degrees.iter().enumerate() {
            self.core.store(v, d);
            self.queued[v].store(false, Ordering::Relaxed);
            self.binned[v].store(false, Ordering::Relaxed);
        }
        self.members.reset();
        self.frontier.reset();
    }
}

/// Run the bucket peel on `g`, leaving coreness in `scratch.core[0..n]`.
/// Returns `(iterations, launches)`. Separated from the trait impl so the
/// flush-time recompute path can pass a long-lived [`BucketScratch`].
pub fn bucket_peel_into(
    g: &CsrGraph,
    threads: usize,
    metrics: &Metrics,
    scratch: &mut BucketScratch,
) -> (usize, usize) {
    let n = g.num_vertices();
    if n == 0 {
        return (0, 0);
    }
    scratch.ensure(n);
    scratch.reset(&g.degrees());
    let BucketScratch {
        core,
        members,
        frontier,
        queued,
        binned,
    } = &*scratch;

    let remaining = AtomicUsize::new(n);
    let iterations = AtomicUsize::new(0);
    let round_end_shared = AtomicUsize::new(0);
    let scan_cursor = AtomicUsize::new(0);
    let scatter_cursor = AtomicUsize::new(0);

    let launches = run_spmd(threads, |ctx| {
        let mv = metrics.view(ctx.tid);

        // Level 0: isolated vertices are already converged (core 0).
        let isolated = ctx.static_chunk(n).filter(|&v| core.load(v) == 0).count();
        if isolated > 0 {
            remaining.fetch_sub(isolated, Ordering::AcqRel);
        }
        ctx.barrier();

        let mut k_lo = 1u32;
        loop {
            // `remaining` only moves under tid 0 between barriers (after
            // the level-0 phase), so this read — and every control-flow
            // read below — is uniform across workers.
            if remaining.load(Ordering::Acquire) == 0 {
                break;
            }
            let k_hi = k_lo.saturating_mul(2).max(k_lo.saturating_add(1));

            // ---- bucket collection: the one full-vertex scan ----
            // V_b = {v : core[v] in [k_lo, k_hi), not yet binned}. The
            // 1-byte stamp short-circuits before the 4-byte core load,
            // and the RMW swap runs at most once per vertex, as in the
            // PeelOne scan.
            let range = ctx.static_chunk(n);
            let lo = range.start;
            for (i, b) in binned[range].iter().enumerate() {
                if !b.load(Ordering::Relaxed) {
                    let v = lo + i;
                    let c = core.load(v);
                    if c >= k_lo && c < k_hi && !b.swap(true, Ordering::Relaxed) {
                        members.push(v as u32);
                        mv.frontier_pushes(1);
                    }
                }
            }
            ctx.launch_boundary();

            // Empty bucket: no remaining vertex has coreness < k_hi (see
            // module docs), so skip straight to the next range.
            if members.pushed() == 0 {
                k_lo = k_hi;
                continue;
            }

            let mut done = false;
            for k in k_lo..k_hi {
                // ---- scan/scatter rounds at level k, members only ----
                let mut round_start = 0usize;
                loop {
                    // scan kernel: V_f = {v in members : core[v] == k,
                    // not yet queued}. The member list is small and
                    // hub-skewed, so chunks are work-stolen.
                    let msize = members.pushed();
                    for range in ctx.dynamic_chunks(msize, 256, &scan_cursor) {
                        for i in range {
                            let v = members.get(i) as usize;
                            let q = &queued[v];
                            if !q.load(Ordering::Relaxed)
                                && core.load(v) == k
                                && !q.swap(true, Ordering::Relaxed)
                            {
                                frontier.push(v as u32);
                                mv.frontier_pushes(1);
                            }
                        }
                    }
                    ctx.launch_boundary();
                    if ctx.tid == 0 {
                        round_end_shared.store(frontier.pushed(), Ordering::Relaxed);
                        scan_cursor.store(0, Ordering::Relaxed);
                    }
                    ctx.barrier();
                    let round_end = round_end_shared.load(Ordering::Relaxed);
                    if round_start == round_end {
                        break;
                    }
                    // scatter kernel over this round's slice
                    let len = round_end - round_start;
                    for range in ctx.dynamic_chunks(len, 32, &scatter_cursor) {
                        for i in range {
                            let v = frontier.get(round_start + i);
                            for &u in g.neighbors(v) {
                                mv.edge_accesses(1);
                                let u = u as usize;
                                if core.load(u) > k {
                                    // assertion method: clamp at the floor k
                                    if let SubFloor::Written(nv) =
                                        atomic_sub_floor(core.cell(u), k, &mv)
                                    {
                                        // dropped into this bucket's range:
                                        // admit it to the local member list
                                        if nv < k_hi {
                                            let b = &binned[u];
                                            if !b.load(Ordering::Relaxed)
                                                && !b.swap(true, Ordering::Relaxed)
                                            {
                                                members.push(u as u32);
                                                mv.frontier_pushes(1);
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                    ctx.launch_boundary();
                    if ctx.tid == 0 {
                        iterations.fetch_add(1, Ordering::Relaxed);
                        scatter_cursor.store(0, Ordering::Relaxed);
                    }
                    round_start = round_end;
                }

                // Level done: everything queued this level had coreness k.
                ctx.barrier();
                if ctx.tid == 0 {
                    remaining.fetch_sub(frontier.pushed(), Ordering::AcqRel);
                    frontier.reset();
                }
                ctx.barrier();
                if remaining.load(Ordering::Acquire) == 0 {
                    done = true;
                    break;
                }
            }
            if done {
                break;
            }
            // Bucket done: the member list is bucket-local; drop it. The
            // stamps stay — a binned vertex never re-enters any bucket.
            if ctx.tid == 0 {
                members.reset();
            }
            ctx.barrier();
            k_lo = k_hi;
        }
    });

    (iterations.load(Ordering::Relaxed), launches)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::{examples, gen};

    #[test]
    fn g1_matches_paper_walkthrough() {
        let r = BucketPeel.decompose_with(&examples::g1(), 2, true);
        assert_eq!(r.core, examples::g1_coreness());
        // assertion method retained: no atomicAdd corrections ever
        assert_eq!(r.metrics.atomic_adds, 0);
    }

    #[test]
    fn matches_bz_on_random_graphs() {
        for seed in 0..3 {
            let g = gen::erdos_renyi(300, 1200, seed);
            let r = BucketPeel.decompose_with(&g, 4, false);
            assert_eq!(r.core, bz_coreness(&g), "seed={seed}");
        }
    }

    #[test]
    fn matches_bz_on_powerlaw_and_planted() {
        let g = gen::barabasi_albert(800, 3, 5);
        assert_eq!(BucketPeel.decompose_with(&g, 4, false).core, bz_coreness(&g));
        let g = gen::planted_core(1000, 3000, &[(200, 12), (50, 25)], 7);
        assert_eq!(BucketPeel.decompose_with(&g, 4, false).core, bz_coreness(&g));
    }

    #[test]
    fn clique_chain_exercises_bucket_skips() {
        // nested cliques span many levels with gaps between them — the
        // empty-bucket fast path and the dynamic member admission both
        // fire here
        let (g, expected) = gen::nested_cliques(4, 3, 4);
        assert_eq!(BucketPeel.decompose_with(&g, 4, false).core, expected);
        let (g, expected) = gen::nested_cliques(6, 5, 9);
        assert_eq!(BucketPeel.decompose_with(&g, 4, false).core, expected);
    }

    #[test]
    fn single_thread_works() {
        let g = gen::rmat(8, 6, 0.57, 0.19, 0.19, 2);
        assert_eq!(BucketPeel.decompose_with(&g, 1, false).core, bz_coreness(&g));
    }

    #[test]
    fn isolated_vertices_terminate() {
        let mut b = crate::graph::GraphBuilder::new(5);
        b.add_edge(0, 1);
        let g = b.build("mostly-isolated");
        let r = BucketPeel.decompose_with(&g, 2, false);
        assert_eq!(r.core, vec![1, 1, 0, 0, 0]);
    }

    #[test]
    fn scratch_reuse_across_runs_is_clean() {
        // A dirtied scratch must not leak stamps or residuals into the
        // next run — this is the flush-path reuse contract.
        let metrics = Metrics::new(2, false);
        let mut scratch = BucketScratch::with_capacity(8);
        let g1 = gen::barabasi_albert(500, 4, 3);
        let g2 = gen::erdos_renyi(200, 700, 9);
        for _ in 0..2 {
            bucket_peel_into(&g1, 2, &metrics, &mut scratch);
            assert_eq!(scratch.core.to_vec()[..500], bz_coreness(&g1)[..]);
            // second graph is smaller: buffers must be reused, prefix-reset
            assert!(scratch.ensure(g2.num_vertices()));
            bucket_peel_into(&g2, 2, &metrics, &mut scratch);
            assert_eq!(scratch.core.to_vec()[..200], bz_coreness(&g2)[..]);
        }
    }

    #[test]
    fn fewer_scan_launches_than_peelone_on_high_kmax() {
        // the point of the buckets: launches track rounds, and member-list
        // rounds don't shrink, but the planted deep core forces PeelOne
        // through every level with full-vertex scans while BucketPeel
        // re-scans only members — equality of results is the hard pin,
        // the launch comparison documents the mechanism stays bounded
        let g = gen::planted_core(2000, 5000, &[(100, 40)], 3);
        let b = BucketPeel.decompose_with(&g, 4, false);
        let p = crate::core::peel::PeelOne.decompose_with(&g, 4, false);
        assert_eq!(b.core, p.core);
        assert!(b.launches <= p.launches + 2 * 64, "b={} p={}", b.launches, p.launches);
    }
}
