//! General Parallel Peel (Algorithm 3) — the common GPU baseline of
//! [19], [20] and Gunrock's k-core: two property arrays (`deg` residual
//! degree + `core` output) plus a `rem` removal flag, full-graph `scan`
//! each round, `scatter` with *unfloored* `atomicSub` guarded by the flag.
//!
//! The paper's critique, reproduced here deliberately:
//! * under-core vertices keep receiving decrements below `k` (wasted
//!   atomics — count them via the metrics to regenerate Fig. 4a);
//! * the scan criterion is multifaceted (`!rem[v] && deg[v] <= k`),
//!   touching two arrays;
//! * `rem` adds a third array of memory traffic.

use crate::core::traits::{DecompositionResult, Decomposer, Paradigm};
use crate::engine::atomics::{atomic_sub_one, AtomicCoreArray};
use crate::engine::frontier::WorkList;
use crate::engine::metrics::Metrics;
use crate::engine::spmd::run_spmd;
use crate::graph::CsrGraph;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Algorithm 3.
#[derive(Clone, Copy, Debug, Default)]
pub struct Gpp;

impl Decomposer for Gpp {
    fn name(&self) -> &'static str {
        "GPP"
    }

    fn paradigm(&self) -> Paradigm {
        Paradigm::Peel
    }

    fn decompose_with(&self, g: &CsrGraph, threads: usize, metrics_on: bool) -> DecompositionResult {
        let n = g.num_vertices();
        let metrics = Metrics::new(threads, metrics_on);
        if n == 0 {
            return DecompositionResult {
                core: vec![],
                iterations: 0,
                launches: 0,
                metrics: metrics.snapshot(),
            };
        }

        let deg = AtomicCoreArray::from_vec(g.degrees());
        let core = AtomicCoreArray::zeros(n);
        let rem: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
        // Frontier buffer: with `rem` set at scan time each vertex enters
        // exactly once across the whole run.
        let frontier = WorkList::new(n);
        let remaining = AtomicUsize::new(n);
        let k = AtomicUsize::new(0);
        let iterations = AtomicUsize::new(0);

        let launches = run_spmd(threads, |ctx| {
            let mv = metrics.view(ctx.tid);
            loop {
                if remaining.load(Ordering::Acquire) == 0 {
                    break;
                }
                let kk = k.load(Ordering::Acquire) as u32;

                // ---- scan kernel: V_f = { v : !rem[v] && deg[v] <= k } ----
                for v in ctx.static_chunk(n) {
                    if !rem[v].load(Ordering::Relaxed) && deg.load(v) <= kk {
                        // mark removed at frontier insertion (Alg 3 line 8)
                        rem[v].store(true, Ordering::Relaxed);
                        core.store(v, kk);
                        frontier.push(v as u32);
                        mv.frontier_pushes(1);
                    }
                }
                ctx.launch_boundary();

                let fsize = frontier.pushed();
                if fsize == 0 {
                    // no vertex at this k: advance k (thread 0)
                    if ctx.tid == 0 {
                        k.fetch_add(1, Ordering::AcqRel);
                    }
                    ctx.barrier();
                    continue;
                }

                // ---- scatter kernel: decrement residual neighbors ----
                for i in ctx.static_chunk(fsize) {
                    let v = frontier.get(i);
                    for &u in g.neighbors(v) {
                        mv.edge_accesses(1);
                        if !rem[u as usize].load(Ordering::Relaxed) {
                            // Unfloored decrement: may sink below k — the
                            // under-core waste PeelOne eliminates.
                            atomic_sub_one(deg.cell(u as usize), &mv);
                        }
                    }
                }
                ctx.launch_boundary();

                if ctx.tid == 0 {
                    iterations.fetch_add(1, Ordering::Relaxed);
                    remaining.fetch_sub(fsize, Ordering::AcqRel);
                    frontier.reset();
                }
                ctx.barrier();
            }
        });

        DecompositionResult {
            core: core.to_vec(),
            iterations: iterations.load(Ordering::Relaxed),
            launches,
            metrics: metrics.snapshot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::{examples, gen};

    #[test]
    fn g1_matches_paper() {
        let r = Gpp.decompose_with(&examples::g1(), 2, false);
        assert_eq!(r.core, examples::g1_coreness());
        assert!(r.iterations >= 3); // Fig. 2: three peel iterations
    }

    #[test]
    fn matches_bz_on_random_graphs() {
        for seed in 0..3 {
            let g = gen::erdos_renyi(300, 1200, seed);
            let r = Gpp.decompose_with(&g, 4, false);
            assert_eq!(r.core, bz_coreness(&g), "seed={seed}");
        }
    }

    #[test]
    fn matches_bz_on_powerlaw() {
        let g = gen::barabasi_albert(800, 3, 5);
        assert_eq!(Gpp.decompose_with(&g, 4, false).core, bz_coreness(&g));
    }

    #[test]
    fn single_thread_works() {
        let g = gen::rmat(8, 6, 0.57, 0.19, 0.19, 2);
        assert_eq!(Gpp.decompose_with(&g, 1, false).core, bz_coreness(&g));
    }

    #[test]
    fn empty_and_isolated() {
        let g = crate::graph::GraphBuilder::new(4).build("iso");
        let r = Gpp.decompose_with(&g, 2, false);
        assert_eq!(r.core, vec![0; 4]);
    }

    #[test]
    fn counts_atomics_when_enabled() {
        // G1: removing v0, v1 at k=1 decrements v5 twice, etc.
        let g = examples::g1();
        let r = Gpp.decompose_with(&g, 2, true);
        assert!(r.metrics.atomic_subs > 0);
        assert!(r.metrics.edge_accesses > 0);
    }
}
