//! PO-dyn — PeelOne combined with the dynamic frontier queue (§III.C
//! step 3): a vertex asserted to the floor `k` mid-scatter is pushed into
//! the live [`WorkList`] and processed *within the same launch*, so each
//! core level costs exactly one scan + one drain and l1 collapses to
//! k_max (Table V). This is the paper's best Peel configuration.

use crate::core::traits::{DecompositionResult, Decomposer, Paradigm};
use crate::engine::atomics::{atomic_sub_floor, sub_floor_seq, AtomicCoreArray, SubFloor};
use crate::engine::frontier::WorkList;
use crate::engine::metrics::Metrics;
use crate::engine::spmd::run_spmd;
use crate::graph::CsrGraph;
use std::sync::atomic::{AtomicUsize, Ordering};

/// PeelOne + dynamic frontier.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoDyn;

impl Decomposer for PoDyn {
    fn name(&self) -> &'static str {
        "PO-dyn"
    }

    fn paradigm(&self) -> Paradigm {
        Paradigm::Peel
    }

    fn decompose_with(&self, g: &CsrGraph, threads: usize, metrics_on: bool) -> DecompositionResult {
        let n = g.num_vertices();
        let metrics = Metrics::new(threads, metrics_on);
        if n == 0 {
            return DecompositionResult {
                core: vec![],
                iterations: 0,
                launches: 0,
                metrics: metrics.snapshot(),
            };
        }

        let core = AtomicCoreArray::from_vec(g.degrees());
        let frontier = WorkList::new(n);
        let remaining = AtomicUsize::new(n);
        let iterations = AtomicUsize::new(0);

        let launches = run_spmd(threads, |ctx| {
            let mv = metrics.view(ctx.tid);

            // Isolated vertices (core 0) are converged from the start.
            let isolated = ctx.static_chunk(n).filter(|&v| core.load(v) == 0).count();
            if isolated > 0 {
                remaining.fetch_sub(isolated, Ordering::AcqRel);
            }
            ctx.barrier();

            let mut k = 0u32;
            loop {
                if remaining.load(Ordering::Acquire) == 0 {
                    break;
                }
                k += 1;

                // ---- scan: seed the level-k frontier ----
                for v in ctx.static_chunk(n) {
                    if core.load(v) == k {
                        frontier.push(v as u32);
                        mv.frontier_pushes(1);
                    }
                }
                ctx.launch_boundary();

                // ---- single drain launch: the dynamic frontier ----
                let seq = ctx.num_threads == 1;
                let process = |v: u32, frontier: &crate::engine::frontier::WorkList| {
                    for &u in g.neighbors(v) {
                        mv.edge_accesses(1);
                        let u = u as usize;
                        if core.load(u) > k {
                            let res = if seq {
                                sub_floor_seq(core.cell(u), k, &mv)
                            } else {
                                atomic_sub_floor(core.cell(u), k, &mv)
                            };
                            if let SubFloor::Written(nv) = res {
                                if nv == k {
                                    // asserted under-core vertex: process
                                    // within this very launch
                                    frontier.push(u as u32);
                                    mv.frontier_pushes(1);
                                }
                            }
                        }
                    }
                };
                if seq {
                    frontier.drain_seq(process);
                } else {
                    frontier.drain(process);
                }
                ctx.launch_boundary();

                if ctx.tid == 0 {
                    iterations.fetch_add(1, Ordering::Relaxed);
                    remaining.fetch_sub(frontier.pushed(), Ordering::AcqRel);
                    frontier.reset();
                }
                ctx.barrier();
            }
        });

        DecompositionResult {
            core: core.to_vec(),
            iterations: iterations.load(Ordering::Relaxed),
            launches,
            metrics: metrics.snapshot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::{examples, gen};

    #[test]
    fn g1_matches_paper() {
        let r = PoDyn.decompose_with(&examples::g1(), 2, false);
        assert_eq!(r.core, examples::g1_coreness());
        // dynamic frontier: l1 equals k_max = 2 (Table V's collapse)
        assert_eq!(r.iterations, 2);
    }

    #[test]
    fn l1_equals_kmax_on_clique_chain() {
        let (g, expected) = gen::nested_cliques(3, 4, 4); // k_max = 11
        let r = PoDyn.decompose_with(&g, 4, false);
        assert_eq!(r.core, expected);
        assert_eq!(r.iterations, 11, "l1 must equal k_max with dyn frontier");
    }

    #[test]
    fn matches_bz_on_random_graphs() {
        for seed in 0..4 {
            let g = gen::erdos_renyi(400, 1600, seed);
            let r = PoDyn.decompose_with(&g, 4, false);
            assert_eq!(r.core, bz_coreness(&g), "seed={seed}");
        }
    }

    #[test]
    fn matches_bz_on_skewed_graphs() {
        let g = gen::rmat(9, 8, 0.57, 0.19, 0.19, 3);
        assert_eq!(PoDyn.decompose_with(&g, 8, false).core, bz_coreness(&g));
        let g = gen::star_burst(3, 200, 400, 5);
        assert_eq!(PoDyn.decompose_with(&g, 8, false).core, bz_coreness(&g));
    }

    #[test]
    fn single_thread_works() {
        let g = gen::barabasi_albert(500, 4, 9);
        assert_eq!(PoDyn.decompose_with(&g, 1, false).core, bz_coreness(&g));
    }

    #[test]
    fn fewer_iterations_than_static_peelone() {
        let g = gen::power_law_cluster(1500, 4, 0.6, 13);
        let dyn_r = PoDyn.decompose_with(&g, 4, false);
        let static_r = crate::core::peel::PeelOne.decompose_with(&g, 4, false);
        assert_eq!(dyn_r.core, static_r.core);
        assert!(
            dyn_r.iterations <= static_r.iterations,
            "dyn {} vs static {}",
            dyn_r.iterations,
            static_r.iterations
        );
    }

    #[test]
    fn no_atomic_adds_ever() {
        let g = gen::barabasi_albert(1000, 5, 21);
        let r = PoDyn.decompose_with(&g, 4, true);
        assert_eq!(r.metrics.atomic_adds, 0);
    }
}
