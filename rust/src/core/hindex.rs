//! The `HINDEX` function shared by all Index2core algorithms (§IV, Fig. 6):
//! for a vertex with neighbor estimates `vals`, the h-index is the largest
//! `h` such that at least `h` neighbors have estimate ≥ `h`.
//!
//! Decomposed exactly as the paper's Step I (histogram, capped at the
//! vertex's own ceiling) + Step II (reverse cumulative sum).

/// Reusable per-worker scratch for histogram construction.
#[derive(Debug, Default)]
pub struct HindexScratch {
    histo: Vec<u32>,
}

impl HindexScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, cap: usize) {
        if self.histo.len() < cap + 1 {
            self.histo.resize(cap + 1, 0);
        }
    }
}

/// h-index of `vals`, capped at `cap` (a vertex's estimate can never
/// exceed its previous estimate, so callers pass the current `core[v]`;
/// capping also bounds the histogram at `cap + 1` slots — the paper's
/// `min(core[u], core[v])` trick).
///
/// Scratch is cleared incrementally (only touched slots), so amortised
/// cost is O(len(vals)) regardless of global max degree.
pub fn hindex_capped(
    vals: impl Iterator<Item = u32> + Clone,
    cap: u32,
    scratch: &mut HindexScratch,
) -> u32 {
    let cap_us = cap as usize;
    scratch.ensure(cap_us);
    // Step I: histogram with values clamped to cap.
    for v in vals.clone() {
        let slot = (v.min(cap)) as usize;
        scratch.histo[slot] += 1;
    }
    // Step II: reverse cumulative sum until sum >= k.
    let mut sum = 0u32;
    let mut h = 0u32;
    let mut k = cap;
    while k >= 1 {
        sum += scratch.histo[k as usize];
        if sum >= k {
            h = k;
            break;
        }
        k -= 1;
    }
    // Incremental clear.
    for v in vals {
        let slot = (v.min(cap)) as usize;
        scratch.histo[slot] = 0;
    }
    h
}

/// Convenience for tests / the oracle: h-index of a slice, no cap beyond
/// its length (h can never exceed the number of values).
pub fn hindex(vals: &[u32]) -> u32 {
    let mut scratch = HindexScratch::new();
    hindex_capped(vals.iter().copied(), vals.len() as u32, &mut scratch)
}

/// `cnt(u)` of CntCore (Alg 5): the number of values ≥ `threshold`.
pub fn cnt_at_least(vals: impl Iterator<Item = u32>, threshold: u32) -> u32 {
    vals.filter(|&v| v >= threshold).count() as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_v5() {
        // Fig. 6: v5's neighbors have estimates {1, 1, 2, 2, 3} -> h = 2.
        assert_eq!(hindex(&[1, 1, 2, 2, 3]), 2);
    }

    #[test]
    fn basic_cases() {
        assert_eq!(hindex(&[]), 0);
        assert_eq!(hindex(&[0]), 0);
        assert_eq!(hindex(&[5]), 1);
        assert_eq!(hindex(&[1, 1, 1]), 1);
        assert_eq!(hindex(&[3, 3, 3]), 3);
        assert_eq!(hindex(&[10, 10, 10, 10]), 4);
        assert_eq!(hindex(&[4, 3, 2, 1]), 2);
    }

    #[test]
    fn cap_bounds_result() {
        let mut s = HindexScratch::new();
        assert_eq!(hindex_capped([9, 9, 9, 9].iter().copied(), 2, &mut s), 2);
        assert_eq!(hindex_capped([9, 9, 9, 9].iter().copied(), 10, &mut s), 4);
    }

    #[test]
    fn scratch_reuse_is_clean() {
        let mut s = HindexScratch::new();
        assert_eq!(hindex_capped([3, 3, 3].iter().copied(), 3, &mut s), 3);
        // if the scratch were dirty, this would over-count
        assert_eq!(hindex_capped([1].iter().copied(), 3, &mut s), 1);
        assert_eq!(hindex_capped([0, 0].iter().copied(), 3, &mut s), 0);
    }

    #[test]
    fn matches_naive_definition() {
        // naive: max h with count(vals >= h) >= h
        let naive = |vals: &[u32]| -> u32 {
            (0..=vals.len() as u32)
                .filter(|&h| vals.iter().filter(|&&v| v >= h).count() as u32 >= h)
                .max()
                .unwrap_or(0)
        };
        let mut rng = crate::util::rng::Rng::new(17);
        for _ in 0..500 {
            let len = rng.below_usize(12);
            let vals: Vec<u32> = (0..len).map(|_| rng.below(10) as u32).collect();
            assert_eq!(hindex(&vals), naive(&vals), "vals={vals:?}");
        }
    }

    #[test]
    fn cnt_matches_definition() {
        assert_eq!(cnt_at_least([1, 2, 3, 4].iter().copied(), 3), 2);
        assert_eq!(cnt_at_least([].iter().copied(), 1), 0);
        assert_eq!(cnt_at_least([5, 5].iter().copied(), 0), 2);
    }
}
