//! NbrCore [19] — the baseline Index2core GPU algorithm: every vertex
//! starts at `core[v] = deg(v)`; each iteration recomputes the h-index of
//! the active set, and **all** neighbors of any vertex whose estimate
//! changed become active next iteration. The paper's Fig. 3 observation:
//! ~94% of those reactivated neighbors do not actually change — the
//! redundancy CntCore then eliminates.

use crate::core::hindex::{hindex_capped, HindexScratch};
use crate::core::traits::{DecompositionResult, Decomposer, Paradigm};
use crate::engine::atomics::AtomicCoreArray;
use crate::engine::frontier::NextFrontier;
use crate::engine::metrics::Metrics;
use crate::engine::spmd::run_spmd;
use crate::graph::CsrGraph;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// The h-index baseline of [19].
#[derive(Clone, Copy, Debug, Default)]
pub struct NbrCore;

impl Decomposer for NbrCore {
    fn name(&self) -> &'static str {
        "NbrCore"
    }

    fn paradigm(&self) -> Paradigm {
        Paradigm::Index2core
    }

    fn decompose_with(&self, g: &CsrGraph, threads: usize, metrics_on: bool) -> DecompositionResult {
        let n = g.num_vertices();
        let metrics = Metrics::new(threads, metrics_on);
        if n == 0 {
            return DecompositionResult {
                core: vec![],
                iterations: 0,
                launches: 0,
                metrics: metrics.snapshot(),
            };
        }

        let core = AtomicCoreArray::from_vec(g.degrees());
        let active: Mutex<Arc<Vec<u32>>> = Mutex::new(Arc::new((0..n as u32).collect()));
        let next = NextFrontier::new(n);
        let cursor = AtomicUsize::new(0);
        let iterations = AtomicUsize::new(0);

        let launches = run_spmd(threads, |ctx| {
            let mv = metrics.view(ctx.tid);
            let mut scratch = HindexScratch::new();
            loop {
                let frontier = active.lock().unwrap().clone();
                if frontier.is_empty() {
                    break;
                }

                // ---- h-index kernel over the active set ----
                for range in ctx.dynamic_chunks(frontier.len(), 64, &cursor) {
                    for &v in &frontier[range] {
                        let v = v as usize;
                        let cap = core.load(v);
                        if cap == 0 {
                            continue;
                        }
                        let nbrs = g.neighbors(v as u32);
                        mv.hindex_evals(1);
                        mv.edge_accesses(nbrs.len() as u64);
                        let h = hindex_capped(
                            nbrs.iter().map(|&u| core.load(u as usize)),
                            cap,
                            &mut scratch,
                        );
                        if h < cap {
                            core.store(v, h);
                            // NbrCore redundancy: reactivate *all* neighbors
                            for &u in nbrs {
                                next.push(u);
                                mv.frontier_pushes(1);
                            }
                        }
                    }
                }
                ctx.launch_boundary();

                if ctx.tid == 0 {
                    iterations.fetch_add(1, Ordering::Relaxed);
                    *active.lock().unwrap() = Arc::new(next.take());
                    cursor.store(0, Ordering::Relaxed);
                }
                ctx.barrier();
            }
        });

        DecompositionResult {
            core: core.to_vec(),
            iterations: iterations.load(Ordering::Relaxed),
            launches,
            metrics: metrics.snapshot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::{examples, gen};

    #[test]
    fn g1_matches_paper() {
        let r = NbrCore.decompose_with(&examples::g1(), 2, false);
        assert_eq!(r.core, examples::g1_coreness());
    }

    #[test]
    fn matches_bz_on_random_graphs() {
        for seed in 0..4 {
            let g = gen::erdos_renyi(400, 1600, seed);
            assert_eq!(NbrCore.decompose_with(&g, 4, false).core, bz_coreness(&g), "seed={seed}");
        }
    }

    #[test]
    fn matches_bz_on_powerlaw() {
        let g = gen::barabasi_albert(1000, 4, 3);
        assert_eq!(NbrCore.decompose_with(&g, 8, false).core, bz_coreness(&g));
    }

    #[test]
    fn clique_chain_exact() {
        let (g, expected) = gen::nested_cliques(3, 4, 3);
        assert_eq!(NbrCore.decompose_with(&g, 4, false).core, expected);
    }

    #[test]
    fn single_thread_works() {
        let g = gen::rmat(8, 6, 0.57, 0.19, 0.19, 4);
        assert_eq!(NbrCore.decompose_with(&g, 1, false).core, bz_coreness(&g));
    }

    #[test]
    fn few_iterations_on_regular_graphs() {
        // On a cycle everything converges immediately (deg == coreness):
        // one sweep with no changes.
        let g = examples::cycle(100);
        let r = NbrCore.decompose_with(&g, 2, false);
        assert_eq!(r.core, vec![2; 100]);
        assert_eq!(r.iterations, 1);
    }

    #[test]
    fn isolated_vertices() {
        let g = crate::graph::GraphBuilder::new(3).build("iso");
        assert_eq!(NbrCore.decompose_with(&g, 2, false).core, vec![0, 0, 0]);
    }
}
