//! CntCore (Algorithm 5) — precise frontier location for Index2core.
//!
//! Theorem 2: `h_u` drops in iteration t **iff** `cnt(u,t) < h_u^{t−1}`,
//! where `cnt` counts neighbors with estimate ≥ the vertex's own. Each
//! iteration therefore (1) computes `cnt` over the active set, (2) runs
//! the expensive HINDEX only on the exact frontier `{cnt < core}`, and
//! (3) reactivates the frontier's neighbors. This removes NbrCore's ~94%
//! wasted h-index evaluations (Fig. 3) at the cost of the cnt pass.

use crate::core::hindex::{cnt_at_least, hindex_capped, HindexScratch};
use crate::core::traits::{DecompositionResult, Decomposer, Paradigm};
use crate::engine::atomics::AtomicCoreArray;
use crate::engine::frontier::{NextFrontier, WorkList};
use crate::engine::metrics::Metrics;
use crate::engine::spmd::run_spmd;
use crate::graph::CsrGraph;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Algorithm 5.
#[derive(Clone, Copy, Debug, Default)]
pub struct CntCore;

impl Decomposer for CntCore {
    fn name(&self) -> &'static str {
        "CntCore"
    }

    fn paradigm(&self) -> Paradigm {
        Paradigm::Index2core
    }

    fn decompose_with(&self, g: &CsrGraph, threads: usize, metrics_on: bool) -> DecompositionResult {
        let n = g.num_vertices();
        let metrics = Metrics::new(threads, metrics_on);
        if n == 0 {
            return DecompositionResult {
                core: vec![],
                iterations: 0,
                launches: 0,
                metrics: metrics.snapshot(),
            };
        }

        let core = AtomicCoreArray::from_vec(g.degrees());
        let active: Mutex<Arc<Vec<u32>>> = Mutex::new(Arc::new((0..n as u32).collect()));
        let frontier = WorkList::new(n);
        let next_active = NextFrontier::new(n);
        let cnt_cursor = AtomicUsize::new(0);
        let est_cursor = AtomicUsize::new(0);
        let iterations = AtomicUsize::new(0);

        let launches = run_spmd(threads, |ctx| {
            let mv = metrics.view(ctx.tid);
            let mut scratch = HindexScratch::new();
            loop {
                let act = active.lock().unwrap().clone();
                if act.is_empty() {
                    break;
                }

                // ---- kernel 1: cnt over active; frontier = {cnt < core} ----
                for range in ctx.dynamic_chunks(act.len(), 64, &cnt_cursor) {
                    for &v in &act[range] {
                        let v = v as usize;
                        let cv = core.load(v);
                        if cv == 0 {
                            continue;
                        }
                        let nbrs = g.neighbors(v as u32);
                        mv.edge_accesses(nbrs.len() as u64);
                        let cnt = cnt_at_least(nbrs.iter().map(|&u| core.load(u as usize)), cv);
                        if cnt < cv {
                            frontier.push(v as u32);
                            mv.frontier_pushes(1);
                        }
                    }
                }
                ctx.launch_boundary();

                // ---- kernel 2: HINDEX on the exact frontier ----
                let fsize = frontier.pushed();
                for range in ctx.dynamic_chunks(fsize, 32, &est_cursor) {
                    for i in range {
                        let v = frontier.get(i) as usize;
                        let cap = core.load(v);
                        let nbrs = g.neighbors(v as u32);
                        mv.hindex_evals(1);
                        mv.edge_accesses(nbrs.len() as u64);
                        let h = hindex_capped(
                            nbrs.iter().map(|&u| core.load(u as usize)),
                            cap,
                            &mut scratch,
                        );
                        debug_assert!(h < cap, "Theorem 2 violated");
                        core.store(v, h);
                        for &u in nbrs {
                            next_active.push(u);
                        }
                    }
                }
                ctx.launch_boundary();

                if ctx.tid == 0 {
                    iterations.fetch_add(1, Ordering::Relaxed);
                    *active.lock().unwrap() = Arc::new(next_active.take());
                    frontier.reset();
                    cnt_cursor.store(0, Ordering::Relaxed);
                    est_cursor.store(0, Ordering::Relaxed);
                }
                ctx.barrier();
            }
        });

        DecompositionResult {
            core: core.to_vec(),
            iterations: iterations.load(Ordering::Relaxed),
            launches,
            metrics: metrics.snapshot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::{examples, gen};

    #[test]
    fn g1_matches_paper() {
        let r = CntCore.decompose_with(&examples::g1(), 2, false);
        assert_eq!(r.core, examples::g1_coreness());
    }

    #[test]
    fn matches_bz_on_random_graphs() {
        for seed in 0..4 {
            let g = gen::erdos_renyi(400, 1600, seed);
            assert_eq!(CntCore.decompose_with(&g, 4, false).core, bz_coreness(&g), "seed={seed}");
        }
    }

    #[test]
    fn matches_bz_on_skewed_graphs() {
        let g = gen::rmat(9, 8, 0.57, 0.19, 0.19, 6);
        assert_eq!(CntCore.decompose_with(&g, 8, false).core, bz_coreness(&g));
        let g = gen::star_burst(3, 150, 300, 8);
        assert_eq!(CntCore.decompose_with(&g, 8, false).core, bz_coreness(&g));
    }

    #[test]
    fn clique_chain_exact() {
        let (g, expected) = gen::nested_cliques(3, 4, 3);
        assert_eq!(CntCore.decompose_with(&g, 4, false).core, expected);
    }

    #[test]
    fn single_thread_works() {
        let g = gen::barabasi_albert(600, 3, 15);
        assert_eq!(CntCore.decompose_with(&g, 1, false).core, bz_coreness(&g));
    }

    #[test]
    fn fewer_hindex_evals_than_nbrcore() {
        // The Fig. 3 claim: precise frontiers cut redundant evaluations.
        let g = gen::barabasi_albert(2000, 4, 77);
        let cnt = CntCore.decompose_with(&g, 4, true);
        let nbr = nbrcore_result(&g);
        assert_eq!(cnt.core, nbr.core);
        assert!(
            cnt.metrics.hindex_evals <= nbr.metrics.hindex_evals,
            "CntCore {} vs NbrCore {}",
            cnt.metrics.hindex_evals,
            nbr.metrics.hindex_evals
        );
    }

    fn nbrcore_result(g: &crate::graph::CsrGraph) -> DecompositionResult {
        crate::core::index2core::NbrCore.decompose_with(g, 4, true)
    }
}
