//! Index2core-paradigm algorithms (top-down h-index convergence, §II-A
//! Algorithm 2): the NbrCore baseline [19], the proposed CntCore (precise
//! frontiers via `cnt`, Alg 5) and HistoCore (up-to-date per-vertex
//! histograms, Alg 6).

pub mod cntcore;
pub mod histocore;
pub mod nbrcore;

pub use cntcore::CntCore;
pub use histocore::HistoCore;
pub use nbrcore::NbrCore;
