//! HistoCore (Algorithm 6) — the paper's flagship Index2core algorithm.
//!
//! CntCore still re-reads every neighbor of a multi-changed frontier to
//! rebuild its histogram (Step I of HINDEX). HistoCore maintains one
//! global, *up-to-date* histogram per vertex:
//!
//! * `InitHisto` builds `histo[v][min(deg(u), deg(v))]++` once;
//! * `SumHisto` recomputes a frontier vertex's estimate by the reverse
//!   cumulative sum alone (Step II) — **no neighbor access** — and stores
//!   the byproduct `sum` into slot `h` (the cnt-slot trick, line 15);
//! * `UpdateHisto` propagates a changed vertex's drop `oldcore → core` to
//!   each neighbor `u` with `core[u] > core[v]` by one atomic decrement at
//!   slot `min(oldcore[v], core[u])` and one increment at `core[v]`; the
//!   decrement's return value crossing `core[u]` is exactly the Theorem-2
//!   frontier signal (lines 19–23).
//!
//! Slots are capped at the owner's current estimate, so when an estimate
//! drops to `h` the suffix `h+1..` of its histogram becomes dead and the
//! stored `sum` re-normalises slot `h` — the capping invariant the tests
//! in `rust/tests/properties.rs` exercise.

use crate::core::traits::{DecompositionResult, Decomposer, Paradigm};
use crate::engine::atomics::AtomicCoreArray;
use crate::engine::frontier::{NextFrontier, WorkList};
use crate::engine::metrics::Metrics;
use crate::engine::spmd::run_spmd;
use crate::graph::CsrGraph;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Algorithm 6.
#[derive(Clone, Copy, Debug, Default)]
pub struct HistoCore;

impl Decomposer for HistoCore {
    fn name(&self) -> &'static str {
        "HistoCore"
    }

    fn paradigm(&self) -> Paradigm {
        Paradigm::Index2core
    }

    fn decompose_with(&self, g: &CsrGraph, threads: usize, metrics_on: bool) -> DecompositionResult {
        let n = g.num_vertices();
        let metrics = Metrics::new(threads, metrics_on);
        if n == 0 {
            return DecompositionResult {
                core: vec![],
                iterations: 0,
                launches: 0,
                metrics: metrics.snapshot(),
            };
        }

        let core = AtomicCoreArray::from_vec(g.degrees());
        let oldcore = AtomicCoreArray::from_vec(g.degrees());

        // Per-vertex histogram rows, flattened: row v has deg(v)+1 slots
        // (estimates are capped at deg(v)), at offset csr_offset[v] + v.
        // Zeroed via memset (atomic_u32_zeroed), not element-wise init —
        // this is an O(2|E|) allocation on the hot path.
        let offsets = g.offsets();
        let row = |v: usize| (offsets[v] as usize) + v;
        let histo: Vec<AtomicU32> =
            crate::engine::atomics::atomic_u32_zeroed(offsets[n] as usize + n);
        // Dense degree array: InitHisto reads deg(u) per arc; going through
        // the 8-byte offsets array doubles the random-access traffic.
        let degs: Vec<u32> = g.degrees();

        let frontier: Mutex<Arc<Vec<u32>>> = Mutex::new(Arc::new((0..n as u32).collect()));
        let changed = WorkList::new(n);
        let vcnt = NextFrontier::new(n);
        let sum_cursor = AtomicUsize::new(0);
        let upd_cursor = AtomicUsize::new(0);
        let iterations = AtomicUsize::new(0);

        let launches = run_spmd(threads, |ctx| {
            let mv = metrics.view(ctx.tid);

            // ---- InitHisto kernel (lines 2–4) ----
            for v in ctx.static_chunk(n) {
                let dv = degs[v];
                let base = row(v);
                for &u in g.neighbors(v as u32) {
                    mv.edge_accesses(1);
                    let slot = degs[u as usize].min(dv) as usize;
                    // row owned by this worker: uncontended add
                    let cell = &histo[base + slot];
                    cell.store(cell.load(Ordering::Relaxed) + 1, Ordering::Relaxed);
                }
            }
            ctx.launch_boundary();

            loop {
                let front = frontier.lock().unwrap().clone();
                if front.is_empty() {
                    break;
                }

                // ---- SumHisto kernel (lines 9–16) ----
                for range in ctx.dynamic_chunks(front.len(), 64, &sum_cursor) {
                    for &v in &front[range] {
                        let v = v as usize;
                        let old = core.load(v);
                        let base = row(v);
                        let mut sum = 0u32;
                        let mut k = old;
                        while k >= 1 {
                            sum += histo[base + k as usize].load(Ordering::Relaxed);
                            if sum >= k {
                                break;
                            }
                            k -= 1;
                        }
                        let h = k;
                        // the paper counts the decoupling win in slot reads,
                        // not neighbor reads:
                        mv.hindex_evals(1);
                        mv.edge_accesses((old - h + 1) as u64);
                        // cnt-slot byproduct (line 15): sum == cnt(v)
                        histo[base + h as usize].store(sum, Ordering::Relaxed);
                        if h != old {
                            core.store(v, h);
                            oldcore.store(v, old);
                            changed.push(v as u32);
                        }
                    }
                }
                ctx.launch_boundary();

                // ---- UpdateHisto kernel (lines 17–23) ----
                // Single-worker runs use plain load/store in place of the
                // LOCK-prefixed RMWs (same semantics, ~15x cheaper; the
                // GPU original pays the same price for both, which is why
                // the paper counts them rather than special-casing).
                let seq = ctx.num_threads == 1;
                let csize = changed.pushed();
                for range in ctx.dynamic_chunks(csize, 32, &upd_cursor) {
                    for i in range {
                        let v = changed.get(i) as usize;
                        let cv = core.load(v);
                        let ov = oldcore.load(v);
                        for &u in g.neighbors(v as u32) {
                            mv.edge_accesses(1);
                            let u = u as usize;
                            let cu = core.load(u);
                            if cu > cv {
                                let base = row(u);
                                let dec_slot = base + ov.min(cu) as usize;
                                let add_slot = base + cv as usize;
                                // CUDA atomicSub returns the OLD value
                                let cnt_value = if seq {
                                    let old = histo[dec_slot].load(Ordering::Relaxed);
                                    histo[dec_slot].store(old - 1, Ordering::Relaxed);
                                    let a = histo[add_slot].load(Ordering::Relaxed);
                                    histo[add_slot].store(a + 1, Ordering::Relaxed);
                                    old
                                } else {
                                    let old = histo[dec_slot].fetch_sub(1, Ordering::Relaxed);
                                    histo[add_slot].fetch_add(1, Ordering::Relaxed);
                                    old
                                };
                                mv.atomic_subs(1);
                                mv.atomic_adds(1);
                                if ov >= cu && cnt_value == cu {
                                    // cnt crossed below core[u]: Theorem-2
                                    // frontier signal
                                    vcnt.push(u as u32);
                                    mv.frontier_pushes(1);
                                }
                            }
                        }
                    }
                }
                ctx.launch_boundary();

                if ctx.tid == 0 {
                    iterations.fetch_add(1, Ordering::Relaxed);
                    *frontier.lock().unwrap() = Arc::new(vcnt.take());
                    changed.reset();
                    sum_cursor.store(0, Ordering::Relaxed);
                    upd_cursor.store(0, Ordering::Relaxed);
                }
                ctx.barrier();
            }
        });

        DecompositionResult {
            core: core.to_vec(),
            iterations: iterations.load(Ordering::Relaxed),
            launches,
            metrics: metrics.snapshot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::{examples, gen};

    #[test]
    fn g1_matches_paper() {
        let r = HistoCore.decompose_with(&examples::g1(), 2, false);
        assert_eq!(r.core, examples::g1_coreness());
    }

    #[test]
    fn matches_bz_on_random_graphs() {
        for seed in 0..4 {
            let g = gen::erdos_renyi(400, 1600, seed);
            assert_eq!(
                HistoCore.decompose_with(&g, 4, false).core,
                bz_coreness(&g),
                "seed={seed}"
            );
        }
    }

    #[test]
    fn matches_bz_on_skewed_graphs() {
        let g = gen::rmat(9, 8, 0.57, 0.19, 0.19, 6);
        assert_eq!(HistoCore.decompose_with(&g, 8, false).core, bz_coreness(&g));
        let g = gen::star_burst(3, 150, 300, 8);
        assert_eq!(HistoCore.decompose_with(&g, 8, false).core, bz_coreness(&g));
    }

    #[test]
    fn matches_bz_on_planted_and_caveman() {
        let g = gen::planted_core(1200, 3600, &[(240, 12), (60, 24)], 19);
        assert_eq!(HistoCore.decompose_with(&g, 4, false).core, bz_coreness(&g));
        let g = gen::caveman(25, 7, 4);
        assert_eq!(HistoCore.decompose_with(&g, 4, false).core, bz_coreness(&g));
    }

    #[test]
    fn clique_chain_exact() {
        let (g, expected) = gen::nested_cliques(3, 4, 3);
        assert_eq!(HistoCore.decompose_with(&g, 4, false).core, expected);
    }

    #[test]
    fn single_thread_works() {
        let g = gen::barabasi_albert(600, 3, 15);
        assert_eq!(HistoCore.decompose_with(&g, 1, false).core, bz_coreness(&g));
    }

    #[test]
    fn fewer_edge_accesses_than_cntcore() {
        // The §IV claim: the up-to-date histo array removes the repeated
        // neighbor sweeps of multi-changed frontiers.
        let g = gen::barabasi_albert(3000, 5, 33);
        let hc = HistoCore.decompose_with(&g, 4, true);
        let cc = crate::core::index2core::CntCore.decompose_with(&g, 4, true);
        assert_eq!(hc.core, cc.core);
        assert!(
            hc.metrics.edge_accesses < cc.metrics.edge_accesses,
            "HistoCore {} vs CntCore {}",
            hc.metrics.edge_accesses,
            cc.metrics.edge_accesses
        );
    }

    #[test]
    fn l2_close_to_cntcore_on_g1() {
        // Both locate frontiers by cnt; sweep counts differ by at most the
        // final empty-frontier check (CntCore counts an active-but-stable
        // sweep, HistoCore exits on an empty V_cnt).
        let hc = HistoCore.decompose_with(&examples::g1(), 1, false);
        let cc = crate::core::index2core::CntCore.decompose_with(&examples::g1(), 1, false);
        assert_eq!(hc.core, cc.core);
        assert!(hc.iterations.abs_diff(cc.iterations) <= 1);
    }

    #[test]
    fn isolated_vertices() {
        let g = crate::graph::GraphBuilder::new(4).build("iso");
        assert_eq!(HistoCore.decompose_with(&g, 2, false).core, vec![0; 4]);
    }
}
