//! The bounded transport: one accept thread, one readiness thread
//! ([`crate::net::poller`]), and a fixed worker pool over a run queue
//! of [`Connection`]s — the replacement for the old
//! thread-per-connection server.
//!
//! Capacity is explicit instead of emergent: `workers` threads
//! (default [`default_workers`]) cooperatively multiplex up to
//! `max_connections` live connections. A connection is a queue entry,
//! not a thread — a worker pops one, serves a bounded slice of requests
//! ([`Connection::serve_slice`]), and either requeues it (more buffered
//! work), hands it to the poller (nothing to do until its socket turns
//! ready), or retires it. Idle connections cost the pool *nothing* per
//! poll interval: they sit in the poller's single `poll(2)` set, and a
//! worker only ever touches a connection the kernel says is readable,
//! writable (staged output), or past a deadline.
//!
//! Accepts past the connection cap are answered with one structured
//! `ERR` line — written best-effort with a short bounded deadline, so
//! a rejected client that never reads cannot block the accept thread —
//! and closed (counted in [`TransportStats::rejected`]). Requests that
//! stall mid-read are timed out (slow-loris,
//! [`TransportStats::timed_out`]); peers that stop draining their
//! replies are cut off ([`TransportStats::write_stalled`]); and while
//! the pool sits *at* the cap, connections idle past
//! [`ConnConfig::idle_reclaim`] give their slot back
//! ([`TransportStats::reclaimed`]) — a horde of cheap idle sockets
//! bounds new-client lockout instead of making it permanent. All
//! counters surface on the `METRICS` verb.
//!
//! # Shutdown
//!
//! [`ServerHandle::stop`] stops the accept loop; live connections keep
//! being served. [`ServerHandle::drain`] additionally asks every
//! connection to close at its next request boundary (in-flight
//! requests finish, staged replies flush — bounded by the stall
//! timeout) and waits for the active gauge to reach zero. Dropping the
//! handle is the hard stop: the poller drops its parked connections,
//! workers abandon whatever is queued, and everything joins.

use super::conn::{ConnConfig, Connection, Handler, Slice, TransportStats};
use super::poller::{Poller, PollerCtx};
use crate::obs::events::{self, Severity};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::io::{ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Pool size when none is configured: one worker per core, capped — a
/// serving box does not need more request-execution threads than that,
/// and the cap keeps `--workers`-less deployments from ballooning on
/// 128-core hosts.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// How long an idle worker watches the connection it just served
/// before handing it to the poller: a request/reply client's next
/// command usually lands within this, and answering it from the worker
/// keeps the hot path off the poller's O(parked) scan entirely — the
/// reason churn qps stays flat as the idle fleet grows.
const WORKER_LINGER: Duration = Duration::from_millis(10);

/// Budget for the final flush of a closing connection (the promised
/// `ERR`/goodbye line) — a live peer takes it instantly off its socket
/// buffer; a dead or malicious one forfeits the courtesy.
const CLOSE_FLUSH_BUDGET: Duration = Duration::from_millis(200);

/// Budget for writing the at-cap reject line from the accept thread.
/// An empty fresh socket buffer makes the write instant for any live
/// peer; the deadline only exists so a peer that never reads cannot
/// block *all* accepts behind its full buffer.
const REJECT_WRITE_BUDGET: Duration = Duration::from_millis(50);

/// Transport configuration for [`serve_handler`].
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Worker threads (0 = [`default_workers`]).
    pub workers: usize,
    /// Hard cap on live connections; accept #cap+1 is answered with an
    /// `ERR` line and closed.
    pub max_connections: usize,
    /// Per-connection read/write/drain knobs + the shard-verb auth
    /// token.
    pub conn: ConnConfig,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            workers: 0,
            max_connections: 1024,
            conn: ConnConfig::default(),
        }
    }
}

/// The run queue shared by the accept loop, the poller, and the
/// workers.
struct RunQueue {
    queue: Mutex<VecDeque<Connection>>,
    ready: Condvar,
}

impl RunQueue {
    fn push(&self, conn: Connection, stats: &TransportStats) {
        let mut q = self.queue.lock().unwrap();
        q.push_back(conn);
        stats.queued.store(q.len(), Ordering::Relaxed);
        drop(q);
        self.ready.notify_one();
    }

    /// Pop the next connection, waiting briefly; `None` on timeout so
    /// callers can re-check their stop flags.
    fn pop_wait(&self, stats: &TransportStats) -> Option<Connection> {
        let mut q = self.queue.lock().unwrap();
        if q.is_empty() {
            let (guard, _timeout) = self
                .ready
                .wait_timeout(q, Duration::from_millis(50))
                .unwrap();
            q = guard;
        }
        let conn = q.pop_front();
        stats.queued.store(q.len(), Ordering::Relaxed);
        conn
    }

    fn clear(&self, stats: &TransportStats) {
        let mut q = self.queue.lock().unwrap();
        q.clear(); // dropping a Connection closes its socket
        stats.queued.store(0, Ordering::Relaxed);
    }
}

/// Decrements the live-connection gauge when the connection it still
/// holds is retired (dropping the socket with it). [`ActiveConn::keep`]
/// disarms the guard for connections going back on the run queue or to
/// the poller (both keep the connection live).
struct ActiveConn {
    conn: Option<Connection>,
    stats: Arc<TransportStats>,
}

impl ActiveConn {
    /// Take the connection back out without retiring it (it stays
    /// live, so the gauge is untouched).
    fn keep(mut self) -> Connection {
        self.conn.take().expect("connection already retired")
    }
}

impl Drop for ActiveConn {
    fn drop(&mut self) {
        if self.conn.is_some() {
            self.stats.active.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Final bounded flush for a connection leaving the pool with a staged
/// goodbye/`ERR` line; dropping the guard afterwards closes the socket
/// and releases the slot.
fn retire(mut active: ActiveConn, budget: Duration) {
    if let Some(conn) = active.conn.as_mut() {
        conn.flush_before_close(budget);
    }
}

/// Best-effort bounded reject: one `ERR` line on a non-blocking
/// socket. The accept thread calls this, so it must never wait on the
/// peer longer than [`REJECT_WRITE_BUDGET`] — a client that never
/// reads simply loses the courtesy line (the close still tells it).
fn reject_over_capacity(mut stream: TcpStream, cap: usize) {
    if stream.set_nonblocking(true).is_err() {
        return;
    }
    let line = format!("ERR server at connection capacity ({cap}); retry later\n");
    let bytes = line.as_bytes();
    let deadline = Instant::now() + REJECT_WRITE_BUDGET;
    let mut written = 0;
    while written < bytes.len() {
        match stream.write(&bytes[written..]) {
            Ok(0) => return,
            Ok(n) => written += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                let now = Instant::now();
                if now >= deadline {
                    return;
                }
                wait_writable(&stream, (deadline - now).min(Duration::from_millis(10)));
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
    // dropping the stream closes it
}

#[cfg(unix)]
fn wait_writable(stream: &TcpStream, timeout: Duration) {
    use super::poller::sys;
    use std::os::unix::io::AsRawFd;
    sys::poll_one(stream.as_raw_fd(), sys::POLLOUT, timeout);
}

#[cfg(not(unix))]
fn wait_writable(_stream: &TcpStream, timeout: Duration) {
    std::thread::sleep(timeout.min(Duration::from_millis(5)));
}

/// A running TCP server. Dropping the handle hard-stops the pool.
pub struct ServerHandle {
    addr: SocketAddr,
    stop_accept: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    hard_stop: Arc<AtomicBool>,
    stats: Arc<TransportStats>,
    queue: Arc<RunQueue>,
    poller: Arc<Poller>,
    joins: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal the accept loop to exit; live connections keep being
    /// served until the handle drops (or [`Self::drain`]).
    pub fn stop(&self) {
        self.stop_accept.store(true, Ordering::SeqCst);
    }

    /// Connections currently live (queued, parked, or being served).
    pub fn active_connections(&self) -> usize {
        self.stats.active.load(Ordering::SeqCst)
    }

    /// The shared transport counters (the `METRICS` verb's source).
    pub fn stats(&self) -> &Arc<TransportStats> {
        &self.stats
    }

    /// Graceful shutdown: stop accepting, ask every connection to close
    /// at its next request boundary (in-flight requests finish and get
    /// their reply; nothing is dropped mid-frame), and wait up to
    /// `grace` for them. Returns whether every connection drained — a
    /// `false` means some connection is stalled mid-request or
    /// mid-flush; it is reclaimed by its stall timeout or by dropping
    /// the handle. Callers flush pending edits afterwards (e.g.
    /// [`crate::service::server::CoreService::flush_all`]).
    pub fn drain(&self, grace: Duration) -> bool {
        events::emit(
            Severity::Info,
            events::kind::DRAIN_START,
            "",
            format!("active={} grace_ms={}", self.active_connections(), grace.as_millis()),
        );
        self.draining.store(true, Ordering::SeqCst);
        self.stop();
        // kick the poller so boundary-idle parked connections are
        // handed to workers (and closed) now, not at the next tick
        self.poller.wake();
        let deadline = std::time::Instant::now() + grace;
        let mut drained = true;
        while self.active_connections() > 0 {
            if std::time::Instant::now() >= deadline {
                drained = false;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        events::emit(
            Severity::Info,
            events::kind::DRAIN_FINISH,
            "",
            format!("drained={drained} remaining={}", self.active_connections()),
        );
        drained
    }

    /// Block until another thread requests a stop ([`Self::stop`] or
    /// [`Self::drain`]), then tear the pool down and return. Useful for
    /// servers run to end-of-process: the calling thread parks here
    /// instead of busy-looping on a flag.
    pub fn join(mut self) {
        while !self.stop_accept.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(50));
        }
        // joining consumes the handle; the Drop impl then has nothing
        // left to do
        self.hard_stop_and_join();
    }

    fn hard_stop_and_join(&mut self) {
        self.stop();
        self.hard_stop.store(true, Ordering::SeqCst);
        self.poller.wake();
        self.queue.ready.notify_all();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
        self.queue.clear(&self.stats);
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.hard_stop_and_join();
    }
}

/// Bind `addr` and serve `handler` on a bounded worker pool until the
/// handle is stopped. The accept thread, the readiness poller, and all
/// workers run in the background; panics in application handlers are
/// contained per request (see [`Connection::serve_slice`]).
pub fn serve_handler(
    handler: Arc<dyn Handler>,
    addr: &str,
    cfg: NetConfig,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr().context("reading bound address")?;
    listener
        .set_nonblocking(true)
        .context("setting the listener non-blocking")?;
    let workers = if cfg.workers == 0 {
        default_workers()
    } else {
        cfg.workers
    };
    let stats = Arc::new(TransportStats::default());
    stats.workers.store(workers, Ordering::Relaxed);
    stats
        .max_connections
        .store(cfg.max_connections, Ordering::Relaxed);
    let stop_accept = Arc::new(AtomicBool::new(false));
    let draining = Arc::new(AtomicBool::new(false));
    let hard_stop = Arc::new(AtomicBool::new(false));
    let queue = Arc::new(RunQueue {
        queue: Mutex::new(VecDeque::new()),
        ready: Condvar::new(),
    });
    let poller = Poller::new().context("creating the readiness poller")?;
    let mut joins = Vec::with_capacity(workers + 2);

    // the readiness thread: parked connections wait here in one
    // poll(2) set instead of rotating through the run queue
    {
        let poller = poller.clone();
        let ctx = PollerCtx {
            cfg: cfg.conn.clone(),
            cap: cfg.max_connections,
            stats: stats.clone(),
            draining: draining.clone(),
            hard_stop: hard_stop.clone(),
            enqueue: {
                let queue = queue.clone();
                let stats = stats.clone();
                Box::new(move |conn| queue.push(conn, &stats))
            },
        };
        joins.push(
            std::thread::Builder::new()
                .name("pico-serve-poller".into())
                .spawn(move || poller.run(ctx))
                .context("spawning the poller thread")?,
        );
    }

    // the accept loop: admission control + enqueue
    {
        let stop = stop_accept.clone();
        let stats = stats.clone();
        let queue = queue.clone();
        let default_graph = handler.default_graph();
        let cap = cfg.max_connections;
        let slot_counter = AtomicUsize::new(0);
        joins.push(
            std::thread::Builder::new()
                .name("pico-serve-accept".into())
                .spawn(move || {
                    while !stop.load(Ordering::SeqCst) {
                        match listener.accept() {
                            Ok((stream, _peer)) => {
                                stats.accepted.fetch_add(1, Ordering::Relaxed);
                                if stats.active.load(Ordering::SeqCst) >= cap {
                                    // one clean error line, then close —
                                    // the client gets a reason, not a
                                    // RST, but only if it actually reads
                                    stats.rejected.fetch_add(1, Ordering::Relaxed);
                                    events::emit(
                                        Severity::Warn,
                                        events::kind::CONN_REJECTED,
                                        "",
                                        format!("at capacity cap={cap}"),
                                    );
                                    reject_over_capacity(stream, cap);
                                    continue;
                                }
                                let slot = slot_counter.fetch_add(1, Ordering::Relaxed);
                                match Connection::new(stream, default_graph.clone(), slot) {
                                    Ok(conn) => {
                                        stats.active.fetch_add(1, Ordering::SeqCst);
                                        queue.push(conn, &stats);
                                    }
                                    Err(_) => {} // socket died during setup
                                }
                            }
                            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(10));
                            }
                            Err(_) => {
                                // transient accept error; keep serving
                                std::thread::sleep(Duration::from_millis(10));
                            }
                        }
                    }
                })
                .context("spawning the accept thread")?,
        );
    }

    // the workers: pop, serve a slice, then requeue / park / retire
    for w in 0..workers {
        let handler = handler.clone();
        let stats = stats.clone();
        let queue = queue.clone();
        let poller = poller.clone();
        let draining = draining.clone();
        let hard_stop = hard_stop.clone();
        let conn_cfg = cfg.conn.clone();
        let cap = cfg.max_connections;
        joins.push(
            std::thread::Builder::new()
                .name(format!("pico-serve-worker-{w}"))
                .spawn(move || {
                    while !hard_stop.load(Ordering::SeqCst) {
                        let Some(conn) = queue.pop_wait(&stats) else {
                            continue;
                        };
                        let mut active = ActiveConn {
                            conn: Some(conn),
                            stats: stats.clone(),
                        };
                        // at the cap, accepts are being rejected: long-
                        // idle connections give their slots back
                        let at_capacity = stats.active.load(Ordering::SeqCst) >= cap;
                        let outcome = active.conn.as_mut().expect("just wrapped").serve_slice(
                            handler.as_ref(),
                            &conn_cfg,
                            &stats,
                            &draining,
                            at_capacity,
                        );
                        match outcome {
                            // on hard stop, dropping `active` closes the
                            // socket and decrements the gauge
                            Slice::Yield | Slice::Park if hard_stop.load(Ordering::SeqCst) => {}
                            Slice::Yield => queue.push(active.keep(), &stats),
                            Slice::Park => {
                                // linger: with nothing else queued,
                                // watch this connection's own fd
                                // briefly — a request/reply client's
                                // next command lands here and never
                                // touches the O(parked) poller scan
                                let conn = active.keep();
                                if stats.queued.load(Ordering::Relaxed) == 0
                                    && !draining.load(Ordering::SeqCst)
                                    && conn.ready_within(&conn_cfg, WORKER_LINGER)
                                {
                                    queue.push(conn, &stats);
                                } else {
                                    poller.park(conn);
                                }
                            }
                            Slice::Closed => retire(active, CLOSE_FLUSH_BUDGET),
                            Slice::TimedOut => {
                                stats.timed_out.fetch_add(1, Ordering::Relaxed);
                                events::emit(
                                    Severity::Warn,
                                    events::kind::SLOW_LORIS_CUTOFF,
                                    "",
                                    "request stalled mid-read past the stall timeout",
                                );
                                retire(active, CLOSE_FLUSH_BUDGET);
                            }
                            Slice::Reclaimed => {
                                stats.reclaimed.fetch_add(1, Ordering::Relaxed);
                                events::emit(
                                    Severity::Info,
                                    events::kind::IDLE_RECLAIM,
                                    "",
                                    "idle connection reclaimed at the connection cap",
                                );
                                retire(active, CLOSE_FLUSH_BUDGET);
                            }
                            Slice::WriteStalled => {
                                // no goodbye flush: the peer provably
                                // stopped reading a stall window ago
                                stats.write_stalled.fetch_add(1, Ordering::Relaxed);
                                events::emit(
                                    Severity::Warn,
                                    events::kind::WRITE_STALL_CUTOFF,
                                    "",
                                    "peer stopped draining staged replies",
                                );
                            }
                        }
                    }
                })
                .context("spawning a pool worker")?,
        );
    }

    Ok(ServerHandle {
        addr: local,
        stop_accept,
        draining,
        hard_stop,
        stats,
        queue,
        poller,
        joins,
    })
}
