//! The bounded transport: one accept thread feeding a fixed worker pool
//! over a run queue of [`Connection`]s — the replacement for the old
//! thread-per-connection server.
//!
//! Capacity is explicit instead of emergent: `workers` threads
//! (default [`default_workers`]) cooperatively multiplex up to
//! `max_connections` live connections. A connection is a queue entry,
//! not a thread — a worker pops one, serves a bounded slice of requests
//! ([`Connection::serve_slice`]), and requeues it, so 16 workers hold
//! thousands of mostly-idle connections at a per-connection cost of one
//! socket + one buffered reader. Accepts past the connection cap are
//! answered with one structured `ERR` line and closed (counted in
//! [`TransportStats::rejected`]); requests that stall mid-read are
//! timed out (slow-loris, [`TransportStats::timed_out`]); and while
//! the pool sits *at* the cap, connections idle past
//! [`ConnConfig::idle_reclaim`] give their slot back
//! ([`TransportStats::reclaimed`]) — a horde of cheap idle sockets
//! bounds new-client lockout instead of making it permanent. All
//! counters surface on the `METRICS` verb.
//!
//! # Shutdown
//!
//! [`ServerHandle::stop`] stops the accept loop; live connections keep
//! being served. [`ServerHandle::drain`] additionally asks every
//! connection to close at its next request boundary (in-flight requests
//! finish and get their reply) and waits for the active gauge to reach
//! zero. Dropping the handle is the hard stop: workers abandon whatever
//! is queued and join.

use super::conn::{ConnConfig, Connection, Handler, Slice, TransportStats};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::io::{ErrorKind, Write};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Pool size when none is configured: one worker per core, capped — a
/// serving box does not need more request-execution threads than that,
/// and the cap keeps `--workers`-less deployments from ballooning on
/// 128-core hosts.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Transport configuration for [`serve_handler`].
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Worker threads (0 = [`default_workers`]).
    pub workers: usize,
    /// Hard cap on live connections; accept #cap+1 is answered with an
    /// `ERR` line and closed.
    pub max_connections: usize,
    /// Per-connection read/drain knobs + the shard-verb auth token.
    pub conn: ConnConfig,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            workers: 0,
            max_connections: 1024,
            conn: ConnConfig::default(),
        }
    }
}

/// The run queue shared by the accept loop and the workers.
struct RunQueue {
    queue: Mutex<VecDeque<Connection>>,
    ready: Condvar,
}

impl RunQueue {
    fn push(&self, conn: Connection, stats: &TransportStats) {
        let mut q = self.queue.lock().unwrap();
        q.push_back(conn);
        stats.queued.store(q.len(), Ordering::Relaxed);
        drop(q);
        self.ready.notify_one();
    }

    /// Pop the next connection, waiting briefly; `None` on timeout so
    /// callers can re-check their stop flags.
    fn pop_wait(&self, stats: &TransportStats) -> Option<Connection> {
        let mut q = self.queue.lock().unwrap();
        if q.is_empty() {
            let (guard, _timeout) = self
                .ready
                .wait_timeout(q, Duration::from_millis(50))
                .unwrap();
            q = guard;
        }
        let conn = q.pop_front();
        stats.queued.store(q.len(), Ordering::Relaxed);
        conn
    }

    fn clear(&self, stats: &TransportStats) {
        let mut q = self.queue.lock().unwrap();
        q.clear(); // dropping a Connection closes its socket
        stats.queued.store(0, Ordering::Relaxed);
    }
}

/// Decrements the live-connection gauge when the connection it still
/// holds is retired (dropping the socket with it). [`ActiveConn::keep`]
/// disarms the guard for connections going back on the run queue.
struct ActiveConn {
    conn: Option<Connection>,
    stats: Arc<TransportStats>,
}

impl ActiveConn {
    /// Take the connection back out without retiring it (it stays
    /// live, so the gauge is untouched).
    fn keep(mut self) -> Connection {
        self.conn.take().expect("connection already retired")
    }
}

impl Drop for ActiveConn {
    fn drop(&mut self) {
        if self.conn.is_some() {
            self.stats.active.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// A running TCP server. Dropping the handle hard-stops the pool.
pub struct ServerHandle {
    addr: SocketAddr,
    stop_accept: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    hard_stop: Arc<AtomicBool>,
    stats: Arc<TransportStats>,
    queue: Arc<RunQueue>,
    joins: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal the accept loop to exit; live connections keep being
    /// served until the handle drops (or [`Self::drain`]).
    pub fn stop(&self) {
        self.stop_accept.store(true, Ordering::SeqCst);
    }

    /// Connections currently live (queued or being served).
    pub fn active_connections(&self) -> usize {
        self.stats.active.load(Ordering::SeqCst)
    }

    /// The shared transport counters (the `METRICS` verb's source).
    pub fn stats(&self) -> &Arc<TransportStats> {
        &self.stats
    }

    /// Graceful shutdown: stop accepting, ask every connection to close
    /// at its next request boundary (in-flight requests finish and get
    /// their reply; nothing is dropped mid-frame), and wait up to
    /// `grace` for them. Returns whether every connection drained — a
    /// `false` means some connection is stalled mid-request; it is
    /// reclaimed by its stall timeout or by dropping the handle.
    /// Callers flush pending edits afterwards (e.g.
    /// [`crate::service::server::CoreService::flush_all`]).
    pub fn drain(&self, grace: Duration) -> bool {
        self.draining.store(true, Ordering::SeqCst);
        self.stop();
        let deadline = std::time::Instant::now() + grace;
        while self.active_connections() > 0 {
            if std::time::Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        true
    }

    /// Block until another thread requests a stop ([`Self::stop`] or
    /// [`Self::drain`]), then tear the pool down and return. Useful for
    /// servers run to end-of-process: the calling thread parks here
    /// instead of busy-looping on a flag.
    pub fn join(mut self) {
        while !self.stop_accept.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(50));
        }
        // joining consumes the handle; the Drop impl then has nothing
        // left to do
        self.hard_stop_and_join();
    }

    fn hard_stop_and_join(&mut self) {
        self.stop();
        self.hard_stop.store(true, Ordering::SeqCst);
        self.queue.ready.notify_all();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
        self.queue.clear(&self.stats);
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.hard_stop_and_join();
    }
}

/// Bind `addr` and serve `handler` on a bounded worker pool until the
/// handle is stopped. The accept thread and all workers run in the
/// background; panics in application handlers are contained per
/// request (see [`Connection::serve_slice`]).
pub fn serve_handler(
    handler: Arc<dyn Handler>,
    addr: &str,
    cfg: NetConfig,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr().context("reading bound address")?;
    listener
        .set_nonblocking(true)
        .context("setting the listener non-blocking")?;
    let workers = if cfg.workers == 0 {
        default_workers()
    } else {
        cfg.workers
    };
    let stats = Arc::new(TransportStats::default());
    stats.workers.store(workers, Ordering::Relaxed);
    stats
        .max_connections
        .store(cfg.max_connections, Ordering::Relaxed);
    let stop_accept = Arc::new(AtomicBool::new(false));
    let draining = Arc::new(AtomicBool::new(false));
    let hard_stop = Arc::new(AtomicBool::new(false));
    let queue = Arc::new(RunQueue {
        queue: Mutex::new(VecDeque::new()),
        ready: Condvar::new(),
    });
    let mut joins = Vec::with_capacity(workers + 1);

    // the accept loop: admission control + enqueue
    {
        let stop = stop_accept.clone();
        let stats = stats.clone();
        let queue = queue.clone();
        let default_graph = handler.default_graph();
        let poll = cfg.conn.poll_timeout;
        let cap = cfg.max_connections;
        let slot_counter = AtomicUsize::new(0);
        joins.push(
            std::thread::Builder::new()
                .name("pico-serve-accept".into())
                .spawn(move || {
                    while !stop.load(Ordering::SeqCst) {
                        match listener.accept() {
                            Ok((mut stream, _peer)) => {
                                stats.accepted.fetch_add(1, Ordering::Relaxed);
                                if stats.active.load(Ordering::SeqCst) >= cap {
                                    // one clean error line, then close —
                                    // the client gets a reason, not a RST
                                    stats.rejected.fetch_add(1, Ordering::Relaxed);
                                    let _ = stream.set_nonblocking(false);
                                    let _ = writeln!(
                                        stream,
                                        "ERR server at connection capacity ({cap}); retry later"
                                    );
                                    continue; // dropping the stream closes it
                                }
                                let slot = slot_counter.fetch_add(1, Ordering::Relaxed);
                                match Connection::new(stream, default_graph.clone(), slot, poll) {
                                    Ok(conn) => {
                                        stats.active.fetch_add(1, Ordering::SeqCst);
                                        queue.push(conn, &stats);
                                    }
                                    Err(_) => {} // socket died during setup
                                }
                            }
                            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(10));
                            }
                            Err(_) => {
                                // transient accept error; keep serving
                                std::thread::sleep(Duration::from_millis(10));
                            }
                        }
                    }
                })
                .context("spawning the accept thread")?,
        );
    }

    // the workers: pop, serve a slice, requeue or retire
    for w in 0..workers {
        let handler = handler.clone();
        let stats = stats.clone();
        let queue = queue.clone();
        let draining = draining.clone();
        let hard_stop = hard_stop.clone();
        let conn_cfg = cfg.conn.clone();
        let cap = cfg.max_connections;
        joins.push(
            std::thread::Builder::new()
                .name(format!("pico-serve-worker-{w}"))
                .spawn(move || {
                    while !hard_stop.load(Ordering::SeqCst) {
                        let Some(conn) = queue.pop_wait(&stats) else {
                            continue;
                        };
                        let mut active = ActiveConn {
                            conn: Some(conn),
                            stats: stats.clone(),
                        };
                        // more live connections than workers: skim idle
                        // ones quickly so ready ones are not held back
                        let live = stats.active.load(Ordering::SeqCst);
                        let oversubscribed = live > workers;
                        // at the cap, accepts are being rejected: long-
                        // idle connections give their slots back
                        let at_capacity = live >= cap;
                        let outcome = active.conn.as_mut().expect("just wrapped").serve_slice(
                            handler.as_ref(),
                            &conn_cfg,
                            &stats,
                            &draining,
                            oversubscribed,
                            at_capacity,
                        );
                        match outcome {
                            Slice::Yield if !hard_stop.load(Ordering::SeqCst) => {
                                // still live: back on the run queue
                                // without touching the active gauge
                                queue.push(active.keep(), &stats);
                            }
                            // on hard stop, dropping `active` closes the
                            // socket and decrements the gauge
                            Slice::Yield | Slice::Closed => {}
                            Slice::TimedOut => {
                                stats.timed_out.fetch_add(1, Ordering::Relaxed);
                            }
                            Slice::Reclaimed => {
                                stats.reclaimed.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                })
                .context("spawning a pool worker")?,
        );
    }

    Ok(ServerHandle {
        addr: local,
        stop_accept,
        draining,
        hard_stop,
        stats,
        queue,
        joins,
    })
}
