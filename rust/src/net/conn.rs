//! The per-connection session state machine, extracted from the old
//! thread-per-connection server: line mode, the `BINARY` framing
//! upgrade, graph pinning, `AUTH` gating of the shard verbs, drain
//! awareness, and slow-loris timeouts.
//!
//! A [`Connection`] owns one socket plus its [`Session`] and is driven
//! cooperatively by the worker pool ([`crate::net::pool`]): each
//! [`Connection::serve_slice`] call reads and answers at most
//! [`MAX_REQUESTS_PER_SLICE`] requests, then yields the connection back
//! to the pool's run queue so a bounded set of workers can multiplex
//! far more connections than threads. Application verbs are delegated
//! through the [`Handler`] trait (implemented by
//! [`crate::service::server::CoreService`]); the transport-owned verbs
//! — `AUTH`, `METRICS` (bare line plus the `PROM`/`JSON` registry
//! expositions), `TRACES`, and the auth gate in front of the shard
//! verbs — are dispatched right here.
//!
//! # Read discipline (slow-loris protection)
//!
//! Reads never pin a worker. The socket is permanently non-blocking; a
//! half-received request is *resumable state on the connection* (the
//! partial line / frame buffer lives in the [`Connection`], not on the
//! worker's stack), so a slow sender is parked with the readiness
//! poller ([`crate::net::poller`]) and costs the pool nothing but its
//! memory until bytes actually arrive. What a slow sender cannot do is
//! hold a request open forever: a request that stops making progress
//! (no bytes for [`ConnConfig::stall_timeout`]) is answered with a
//! structured `ERR` and the connection is closed, counted in
//! [`TransportStats::timed_out`]. Draining is honoured at request
//! boundaries only — an in-flight request keeps being served across
//! slices until it completes and is answered in full; a half-read frame
//! is never dropped.
//!
//! # Write discipline (backpressure)
//!
//! Writes never pin a worker either. Replies are *staged* on the
//! connection's bounded outbound buffer ([`OutBuf`], internal) and
//! flushed with non-blocking writes, driven by writability events from
//! the poller. A peer that reads slowly accumulates staged bytes up to
//! [`ConnConfig::out_hwm`], at which point the connection stops
//! *reading* (explicit backpressure — no new requests are consumed
//! until the peer drains replies); a peer that stops reading entirely
//! is cut off once the staged output makes no progress for a full
//! [`ConnConfig::stall_timeout`], counted in
//! [`TransportStats::write_stalled`]. Per-connection memory is thereby
//! bounded by the high-water mark plus one in-flight reply.

use super::codec::{self, MAX_FRAME_BYTES, MAX_LINE_BYTES};
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::TcpStream;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// Most requests one [`Connection::serve_slice`] answers before the
/// connection yields back to the run queue — fairness: a client
/// pipelining thousands of commands must not starve the other
/// connections sharing its worker.
pub const MAX_REQUESTS_PER_SLICE: usize = 32;

/// Every line-protocol verb this layer dispatches (transport-owned or
/// delegated to the [`Handler`]). CI greps this table against the
/// protocol docs in [`crate::service::server`] — a verb added here
/// without a documentation row fails the lint job.
pub const LINE_VERBS: &[&str] = &[
    "PING",
    "GRAPHS",
    "USE",
    "OPEN",
    "EPOCH",
    "CORENESS",
    "DEGENERACY",
    "MEMBERS",
    "HISTO",
    "DENSEST",
    "SHARDS",
    "CLUSTER",
    "INSERT",
    "DELETE",
    "FLUSH",
    "STATS",
    "METRICS",
    "TRACES",
    "EVENTS",
    "HEALTH",
    "AUTH",
    "BINARY",
    "QUIT",
    "SHARDINFO",
    "SHARDCORE",
    "SHARDHISTO",
];

/// The binary-frame verbs (head line of a frame; any line verb works in
/// a frame too). Drift-checked against the docs like [`LINE_VERBS`].
pub const FRAME_VERBS: &[&str] = &[
    "SNAPSHOT",
    "RESTORE",
    "SHARDHOST",
    "SHARDSNAP",
    "SHARDAPPLY",
    "SHARDREFINE",
    "SHARDDELTA",
    "SHARDHAND",
    "SHARDMEMBERS",
];

/// Verbs gated behind an `AUTH <token>` preamble whenever the server
/// has a token configured ([`ConnConfig::auth_token`]): everything that
/// installs or mutates hosted shard state.
pub const AUTH_VERBS: &[&str] = &[
    "SHARDHOST",
    "SHARDAPPLY",
    "SHARDREFINE",
    "SHARDSNAP",
    "SHARDDELTA",
    "SHARDHAND",
];

/// The `CLUSTER <SUBVERB>` admin namespace — the one dispatch table the
/// control plane hangs off. [`crate::service::server`] resolves the
/// sub-verb against this list (and each legacy alias against
/// [`CLUSTER_ALIASES`]); CI greps every entry here against the protocol
/// docs as `` `CLUSTER <SUB>` ``, so a namespace addition cannot land
/// undocumented.
pub const CLUSTER_SUBVERBS: &[&str] = &["TOPOLOGY", "REBALANCE", "MOVES"];

/// Legacy admin verbs kept as thin aliases for one release: each pair
/// is `(old verb, CLUSTER sub-verb it forwards to)`. Both spellings run
/// the identical handler, so replies are byte-for-byte equal (pinned by
/// an alias-equivalence test in `tests/cluster.rs`).
pub const CLUSTER_ALIASES: &[(&str, &str)] = &[("SHARDS", "TOPOLOGY")];

/// Stable machine-readable error codes for the `ERR <CODE> <msg>` reply
/// shape produced by [`err_reply`] — what `net/client.rs` parses so
/// retry/failover decisions key off a code instead of string-matching
/// free text.
pub mod code {
    /// Missing or wrong `AUTH <token>` preamble.
    pub const AUTH: &str = "AUTH";
    /// No graph selected / graph does not exist.
    pub const NOGRAPH: &str = "NOGRAPH";
    /// Epoch fence: the request's epoch does not match the shard's
    /// (stale delta chain base, stale read during a move).
    pub const STALE_EPOCH: &str = "STALE_EPOCH";
    /// The answer lives on another host (reserved; the `REDIRECT` reply
    /// head carries the address today).
    pub const REDIRECT: &str = "REDIRECT";
    /// A server-side limit: graph cap, edit-queue cap, connection cap.
    pub const CAPACITY: &str = "CAPACITY";
    /// Malformed request (usage errors, oversized lines/frames).
    pub const BADREQ: &str = "BADREQ";
    /// A rebalance is already in flight; retry after it completes.
    pub const MIGRATING: &str = "MIGRATING";
    /// Every stable code — the client-side parser's allow-list.
    pub const ALL: &[&str] = &[
        AUTH,
        NOGRAPH,
        STALE_EPOCH,
        REDIRECT,
        CAPACITY,
        BADREQ,
        MIGRATING,
    ];
}

/// The one place `ERR <CODE> <msg>` replies are formatted. Codes come
/// from [`code`]; anything else is a programming error (debug-asserted)
/// — free-text `ERR` without a code remains legal protocol, this helper
/// is for the sites whose errors drive client retry/failover decisions.
pub fn err_reply(c: &str, msg: impl std::fmt::Display) -> String {
    debug_assert!(code::ALL.contains(&c), "unknown ERR code {c}");
    format!("ERR {c} {msg}")
}

/// Per-connection state.
#[derive(Clone, Debug)]
pub struct Session {
    /// Current graph name.
    pub graph: String,
    /// Whether the connection has upgraded to binary framing.
    pub binary: bool,
    /// Whether an `AUTH` preamble matched the server's token (stays
    /// `false` on open servers; the gate only checks it when a token is
    /// configured).
    pub authed: bool,
}

impl Session {
    pub fn new(graph: impl Into<String>) -> Self {
        Self {
            graph: graph.into(),
            binary: false,
            authed: false,
        }
    }
}

/// The application half of the protocol: everything that is not
/// transport (framing, auth, metrics) is delegated here.
pub trait Handler: Send + Sync + 'static {
    /// The graph a fresh session starts on.
    fn default_graph(&self) -> String;
    /// Execute one protocol line; returns the reply line (no newline).
    fn handle_line(&self, session: &mut Session, line: &str, slot: usize) -> String;
    /// Execute one binary frame body; returns the reply frame body.
    fn handle_frame(&self, session: &mut Session, body: &[u8], slot: usize) -> Vec<u8>;
}

/// Transport knobs shared by every connection of one server.
#[derive(Clone, Debug)]
pub struct ConnConfig {
    /// Upper bound on the readiness thread's poll tick — how stale the
    /// deadline sweep (stall, write-stall, at-cap idle reclaim, drain)
    /// can get. Readable/writable sockets wake the poller immediately
    /// regardless of this.
    pub poll_timeout: Duration,
    /// Longest a started request may go without delivering a byte —
    /// and, symmetrically, the longest staged output may go without
    /// the peer accepting a byte — before the connection is cut off
    /// (slow-loris bound, both directions).
    pub stall_timeout: Duration,
    /// Once the pool is at its connection cap (and only then), idle
    /// connections that have not completed a request for this long are
    /// reclaimed — a clean `ERR` and a close — so a horde of cheap idle
    /// sockets bounds new-client lockout instead of making it
    /// permanent. Off the cap, idle connections live forever (sticky
    /// cluster clients depend on that).
    pub idle_reclaim: Duration,
    /// High-water mark on a connection's staged outbound bytes: while
    /// more than this is waiting to flush, the connection stops
    /// *reading* (backpressure) until the peer drains its replies.
    /// Per-connection memory is bounded by this plus one in-flight
    /// reply (a single reply — e.g. a snapshot frame — may itself
    /// exceed the mark; it is staged whole, then gates further reads).
    pub out_hwm: usize,
    /// When set, the shard verbs in [`AUTH_VERBS`] require a matching
    /// `AUTH <token>` preamble on the connection first.
    pub auth_token: Option<String>,
}

impl Default for ConnConfig {
    fn default() -> Self {
        Self {
            poll_timeout: Duration::from_millis(100),
            stall_timeout: Duration::from_secs(30),
            idle_reclaim: Duration::from_secs(60),
            out_hwm: 256 << 10,
            auth_token: None,
        }
    }
}

/// Shared transport counters, surfaced by the `METRICS` verb and
/// [`crate::net::pool::ServerHandle`].
#[derive(Debug, Default)]
pub struct TransportStats {
    /// Connections the accept loop took off the listener.
    pub accepted: AtomicU64,
    /// Connections refused because the server was at its connection cap.
    pub rejected: AtomicU64,
    /// Connections closed for stalling mid-request (slow-loris).
    pub timed_out: AtomicU64,
    /// Connections cut off because the peer stopped draining staged
    /// replies for a full stall window (write-side slow-loris).
    pub write_stalled: AtomicU64,
    /// Idle connections reclaimed while the pool sat at its cap.
    pub reclaimed: AtomicU64,
    /// Live connections (queued or being served).
    pub active: AtomicUsize,
    /// Connections sitting in the run queue right now.
    pub queued: AtomicUsize,
    /// Pool size / connection cap, fixed at serve time (stored here so
    /// the `METRICS` reply needs no reach into the pool).
    pub workers: AtomicUsize,
    pub max_connections: AtomicUsize,
}

impl TransportStats {
    /// Publish the transport counters into the global observability
    /// registry — called at scrape time (`METRICS PROM|JSON`), so the
    /// accept/serve hot paths keep their existing single atomics.
    pub fn publish(&self) {
        use crate::obs::names;
        let reg = crate::obs::global();
        reg.counter(names::NET_ACCEPTED, &[])
            .set_total(self.accepted.load(Ordering::Relaxed));
        reg.counter(names::NET_REJECTED, &[])
            .set_total(self.rejected.load(Ordering::Relaxed));
        reg.counter(names::NET_TIMED_OUT, &[])
            .set_total(self.timed_out.load(Ordering::Relaxed));
        reg.counter(names::NET_WRITE_STALLED, &[])
            .set_total(self.write_stalled.load(Ordering::Relaxed));
        reg.counter(names::NET_RECLAIMED, &[])
            .set_total(self.reclaimed.load(Ordering::Relaxed));
        reg.gauge(names::NET_ACTIVE, &[])
            .set(self.active.load(Ordering::Relaxed) as u64);
        reg.gauge(names::NET_QUEUED, &[])
            .set(self.queued.load(Ordering::Relaxed) as u64);
        reg.gauge(names::NET_WORKERS, &[])
            .set(self.workers.load(Ordering::Relaxed) as u64);
        reg.gauge(names::NET_CONN_CAP, &[])
            .set(self.max_connections.load(Ordering::Relaxed) as u64);
    }

    /// The `METRICS` reply line.
    pub fn metrics_line(&self) -> String {
        format!(
            "OK workers={} conn_cap={} accepted={} active={} queued={} rejected={} timed_out={} write_stalled={} reclaimed={}",
            self.workers.load(Ordering::Relaxed),
            self.max_connections.load(Ordering::Relaxed),
            self.accepted.load(Ordering::Relaxed),
            self.active.load(Ordering::Relaxed),
            self.queued.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.timed_out.load(Ordering::Relaxed),
            self.write_stalled.load(Ordering::Relaxed),
            self.reclaimed.load(Ordering::Relaxed),
        )
    }
}

/// The `PICO_AUTH_TOKEN` env token, when set non-empty — the one
/// lookup the serve side (the gate in [`ConnConfig::auth_token`]) and
/// every dialer (the `AUTH` preamble) share, so the two cannot drift.
/// A token containing whitespace cannot be carried by the line-based
/// `AUTH <token>` verb (only the first token would survive parsing),
/// so it is rejected loudly here — the same rule the topology parser
/// enforces — instead of configuring a gate no client could pass.
pub fn env_auth_token() -> Option<String> {
    match std::env::var("PICO_AUTH_TOKEN") {
        Ok(t) if t.contains(char::is_whitespace) => {
            eprintln!(
                "warning: PICO_AUTH_TOKEN contains whitespace, which the AUTH verb cannot carry; ignoring it"
            );
            None
        }
        Ok(t) if !t.is_empty() => Some(t),
        _ => None,
    }
}

/// Constant-time byte equality for equal-length inputs: the comparison
/// touches every byte regardless of where they first differ, so reply
/// timing does not leak a prefix match of the auth token. A length
/// mismatch returns early — length is not secret material here, and
/// folding it into a narrowed accumulator is exactly the bug class
/// (lengths differing by a multiple of 256 comparing equal) this
/// explicit check rules out.
pub fn ct_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut diff = 0u8;
    for (x, y) in a.iter().zip(b) {
        diff |= x ^ y;
    }
    diff == 0
}

/// Why a [`Connection::serve_slice`] returned.
#[derive(Debug, PartialEq, Eq)]
pub enum Slice {
    /// Out of slice budget with the connection still runnable —
    /// requeue directly (fairness, not idleness).
    Yield,
    /// Nothing to do until the socket turns readable — or writable,
    /// with staged output pending — hand to the readiness poller.
    Park,
    /// Peer closed, `QUIT`, a fatal protocol error, or drained — drop.
    Closed,
    /// Stalled mid-request past the stall timeout — drop and count.
    TimedOut,
    /// The peer stopped accepting staged replies for a full stall
    /// window — drop and count ([`TransportStats::write_stalled`]).
    /// No goodbye is flushed: the peer provably is not reading.
    WriteStalled,
    /// Idle past [`ConnConfig::idle_reclaim`] while the pool sat at its
    /// connection cap — drop and count, freeing the slot.
    Reclaimed,
}

/// What one read step produced.
enum ReadStep<T> {
    /// A complete request.
    Data(T),
    /// No request pending at all (a drainable boundary).
    Idle,
    /// Mid-request, peer alive but slow — yield, resume next slice.
    Pending,
    /// Clean EOF at a request boundary.
    Closed,
}

impl<T> ReadStep<T> {
    fn map<U>(self, f: impl FnOnce(T) -> U) -> ReadStep<U> {
        match self {
            ReadStep::Data(t) => ReadStep::Data(f(t)),
            ReadStep::Idle => ReadStep::Idle,
            ReadStep::Pending => ReadStep::Pending,
            ReadStep::Closed => ReadStep::Closed,
        }
    }
}

/// A complete request in either mode.
enum Req {
    Line(String),
    Frame(Vec<u8>),
}

/// Resumable read state for the request currently crossing the wire —
/// this living on the connection (not a worker's stack) is what lets a
/// bounded pool survive slow senders.
enum Partial {
    None,
    Line(Vec<u8>),
    Frame(FramePartial),
}

struct FramePartial {
    header: [u8; codec::FRAME_HEADER_BYTES],
    hfilled: usize,
    /// Allocated once the header completes.
    body: Option<Vec<u8>>,
    bfilled: usize,
}

impl FramePartial {
    fn fresh() -> Self {
        Self {
            header: [0u8; codec::FRAME_HEADER_BYTES],
            hfilled: 0,
            body: None,
            bfilled: 0,
        }
    }
}

/// The bounded staging buffer for one connection's outbound bytes.
/// Replies are staged here and flushed with non-blocking writes — a
/// worker never blocks in `write(2)` on a peer that stopped reading.
struct OutBuf {
    buf: Vec<u8>,
    /// Bytes of `buf` already written to the socket.
    pos: usize,
    /// Last time the socket accepted a byte (write-stall clock; reset
    /// when staging into an empty buffer).
    last_progress: Instant,
}

impl OutBuf {
    fn new() -> Self {
        Self {
            buf: Vec::new(),
            pos: 0,
            last_progress: Instant::now(),
        }
    }

    /// Bytes staged but not yet accepted by the socket.
    fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Write as much staged output as the socket takes right now.
    /// `WouldBlock` is not an error here (the poller's writability
    /// event resumes the flush); `Err` means the peer is gone.
    fn flush_to(&mut self, w: &mut impl Write) -> std::io::Result<()> {
        while self.pending() > 0 {
            match w.write(&self.buf[self.pos..]) {
                Ok(0) => return Err(std::io::Error::from(ErrorKind::WriteZero)),
                Ok(n) => {
                    self.pos += n;
                    self.last_progress = Instant::now();
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        if self.pending() == 0 {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos >= 64 << 10 {
            // keep the resident tail small while a slow peer drains
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        Ok(())
    }
}

impl Write for OutBuf {
    /// Staging is infallible — bounding happens at the read side
    /// (backpressure over [`ConnConfig::out_hwm`]) and the write-stall
    /// cutoff, never by failing a reply mid-format.
    fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
        if self.pending() == 0 {
            self.buf.clear();
            self.pos = 0;
            self.last_progress = Instant::now();
        }
        self.buf.extend_from_slice(data);
        Ok(data.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// One live connection: socket, buffered reader, staged outbound
/// bytes, session, and the resumable read state of the in-flight
/// request.
pub struct Connection {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    out: OutBuf,
    session: Session,
    slot: usize,
    partial: Partial,
    /// Last time the in-flight request delivered a byte (stall clock).
    last_progress: Instant,
    /// Last time a request completed (idle-reclaim clock).
    last_active: Instant,
}

impl Connection {
    /// Wrap an accepted stream. The socket goes (and stays)
    /// non-blocking: reads return `WouldBlock` instead of waiting, and
    /// the readiness poller decides when the connection runs again.
    pub fn new(stream: TcpStream, default_graph: String, slot: usize) -> std::io::Result<Self> {
        stream.set_nonblocking(true)?;
        let writer = stream.try_clone()?;
        Ok(Self {
            reader: BufReader::new(stream),
            writer,
            out: OutBuf::new(),
            session: Session::new(default_graph),
            slot,
            partial: Partial::None,
            last_progress: Instant::now(),
            last_active: Instant::now(),
        })
    }

    /// Serve up to [`MAX_REQUESTS_PER_SLICE`] requests, then yield.
    /// `draining` is honoured at request boundaries only. The write
    /// side runs first: staged output is flushed, a peer that stopped
    /// accepting bytes for a full stall window is cut off
    /// ([`Slice::WriteStalled`]), and a connection over its outbound
    /// high-water mark parks without reading (backpressure).
    pub fn serve_slice(
        &mut self,
        handler: &dyn Handler,
        cfg: &ConnConfig,
        stats: &TransportStats,
        draining: &AtomicBool,
        at_capacity: bool,
    ) -> Slice {
        if self.out.flush_to(&mut self.writer).is_err() {
            return Slice::Closed;
        }
        if self.out.pending() > 0 {
            if self.out.last_progress.elapsed() >= cfg.stall_timeout {
                return Slice::WriteStalled;
            }
            if self.out.pending() > cfg.out_hwm {
                return Slice::Park;
            }
        }
        for _served in 0..MAX_REQUESTS_PER_SLICE {
            let step = if self.session.binary {
                match self.read_frame_step(cfg.stall_timeout) {
                    Ok(s) => s.map(Req::Frame),
                    Err(e) => return self.read_error(e),
                }
            } else {
                match self.read_line_step(cfg.stall_timeout) {
                    Ok(s) => s.map(Req::Line),
                    Err(e) => return self.read_error(e),
                }
            };
            match step {
                ReadStep::Data(req) => {
                    if !self.answer(handler, cfg, stats, req) {
                        return Slice::Closed;
                    }
                    self.last_active = Instant::now();
                    if self.out.flush_to(&mut self.writer).is_err() {
                        return Slice::Closed;
                    }
                    if self.out.pending() > cfg.out_hwm {
                        // backpressure: no read-ahead for a peer that
                        // is not draining its replies
                        return Slice::Park;
                    }
                }
                ReadStep::Idle => {
                    if self.out.pending() > 0 {
                        // boundary with staged output: park on
                        // writability and finish the flush first
                        return Slice::Park;
                    }
                    if draining.load(Ordering::SeqCst) {
                        return Slice::Closed;
                    }
                    // at the connection cap, long-idle sockets give
                    // their slot back (a horde of cheap idle sockets
                    // must not lock new clients out forever); off the
                    // cap, idle connections live indefinitely
                    if at_capacity && self.last_active.elapsed() >= cfg.idle_reclaim {
                        self.send_err(&err_reply(
                            code::CAPACITY,
                            "connection reclaimed (server at capacity, idle too long)",
                        ));
                        return Slice::Reclaimed;
                    }
                    return Slice::Park;
                }
                // mid-request: park with the partial state kept —
                // drain waits for the boundary, the stall clock runs
                ReadStep::Pending => return Slice::Park,
                ReadStep::Closed => return Slice::Closed,
            }
            if draining.load(Ordering::SeqCst) {
                return Slice::Closed;
            }
        }
        Slice::Yield
    }

    /// Whether the connection sits at a request boundary (no partial
    /// request buffered).
    pub(crate) fn at_boundary(&self) -> bool {
        matches!(self.partial, Partial::None)
    }

    /// A drain can close this connection as-is: request boundary and
    /// nothing left to flush.
    pub(crate) fn drain_closable(&self) -> bool {
        self.at_boundary() && self.out.pending() == 0
    }

    /// The readiness the poller should watch, as `(read, write)`:
    /// write interest while staged output is pending, read interest
    /// unless backpressure (staged output over the high-water mark)
    /// says the peer has to drain first.
    pub(crate) fn poll_interest(&self, cfg: &ConnConfig) -> (bool, bool) {
        let pending = self.out.pending();
        (pending <= cfg.out_hwm, pending > 0)
    }

    /// When the poller must hand this connection back to a worker even
    /// without socket readiness: read-stall, write-stall, or (at the
    /// connection cap) idle reclaim. `None` parks indefinitely.
    pub(crate) fn next_deadline(&self, cfg: &ConnConfig, at_capacity: bool) -> Option<Instant> {
        let mut due: Option<Instant> = None;
        let mut fold = |d: Instant| due = Some(due.map_or(d, |cur: Instant| cur.min(d)));
        if !self.at_boundary() {
            fold(self.last_progress + cfg.stall_timeout);
        }
        if self.out.pending() > 0 {
            fold(self.out.last_progress + cfg.stall_timeout);
        }
        if at_capacity && self.drain_closable() {
            fold(self.last_active + cfg.idle_reclaim);
        }
        due
    }

    /// The socket fd the poller watches.
    #[cfg(unix)]
    pub(crate) fn fd(&self) -> std::os::unix::io::RawFd {
        use std::os::unix::io::AsRawFd;
        self.writer.as_raw_fd()
    }

    /// Wait up to `timeout` for this socket to match the connection's
    /// current interest — the worker "linger" that keeps a chatty
    /// request/reply client off the poller's O(parked) scan entirely.
    pub(crate) fn ready_within(&self, cfg: &ConnConfig, timeout: Duration) -> bool {
        #[cfg(unix)]
        {
            use super::poller::sys;
            let (read, write) = self.poll_interest(cfg);
            let mut events = 0i16;
            if read {
                events |= sys::POLLIN;
            }
            if write {
                events |= sys::POLLOUT;
            }
            sys::poll_one(self.fd(), events, timeout)
        }
        #[cfg(not(unix))]
        {
            let _ = (cfg, timeout);
            false
        }
    }

    /// Last-gasp bounded flush for a closing connection: the promised
    /// `ERR`/goodbye line should reach a live peer, but a dead or
    /// malicious one must not hold a worker past `budget`.
    pub(crate) fn flush_before_close(&mut self, budget: Duration) {
        let deadline = Instant::now() + budget;
        loop {
            if self.out.flush_to(&mut self.writer).is_err() || self.out.pending() == 0 {
                return;
            }
            let now = Instant::now();
            if now >= deadline {
                return;
            }
            let wait = (deadline - now).min(Duration::from_millis(20));
            #[cfg(unix)]
            {
                use super::poller::sys;
                sys::poll_one(self.fd(), sys::POLLOUT, wait);
            }
            #[cfg(not(unix))]
            std::thread::sleep(wait);
        }
    }

    /// Stage a structured `ERR` in whichever framing the session
    /// speaks — the one place the mode branch lives, so line and
    /// binary error behavior cannot drift apart. Delivery happens on
    /// the connection's final bounded flush
    /// ([`Connection::flush_before_close`]).
    fn send_err(&mut self, msg: &str) {
        let _ = if self.session.binary {
            codec::write_frame(&mut self.out, msg.as_bytes())
        } else {
            writeln!(self.out, "{msg}")
        };
    }

    /// Map a fatal read error to a slice outcome, sending the
    /// structured `ERR` the protocol promises where one applies.
    fn read_error(&mut self, e: std::io::Error) -> Slice {
        match e.kind() {
            ErrorKind::TimedOut => {
                // slow-loris: a started request stopped making progress
                self.send_err("ERR read timed out mid-request (slow sender)");
                Slice::TimedOut
            }
            ErrorKind::InvalidData => {
                // oversized line/frame: structured error, then close
                let msg = if self.session.binary {
                    err_reply(code::BADREQ, format!("frame exceeds {MAX_FRAME_BYTES} bytes"))
                } else {
                    err_reply(code::BADREQ, format!("line exceeds {MAX_LINE_BYTES} bytes"))
                };
                self.send_err(&msg);
                Slice::Closed
            }
            _ => Slice::Closed,
        }
    }

    /// Dispatch one complete request and *stage* its reply on the
    /// outbound buffer (the caller flushes). Returns whether the
    /// connection stays open.
    fn answer(
        &mut self,
        handler: &dyn Handler,
        cfg: &ConnConfig,
        stats: &TransportStats,
        req: Req,
    ) -> bool {
        match req {
            Req::Line(line) => {
                if line.trim().is_empty() {
                    return true;
                }
                let reply = match self.transport_reply(cfg, stats, &line) {
                    Some(r) => r,
                    // containment: a panicking handler must not take
                    // the server down — the connection reports and
                    // closes, the pool lives
                    None => std::panic::catch_unwind(AssertUnwindSafe(|| {
                        handler.handle_line(&mut self.session, &line, self.slot)
                    }))
                    .unwrap_or_else(|_| "ERR internal handler panic (contained)".into()),
                };
                let quit = reply == "OK bye";
                if writeln!(self.out, "{reply}").is_err() {
                    return false;
                }
                !quit
            }
            Req::Frame(body) => {
                let (head, _) = codec::split_frame(&body);
                let reply = match std::str::from_utf8(head)
                    .ok()
                    .and_then(|h| self.transport_reply(cfg, stats, h))
                {
                    Some(r) => r.into_bytes(),
                    None => std::panic::catch_unwind(AssertUnwindSafe(|| {
                        handler.handle_frame(&mut self.session, &body, self.slot)
                    }))
                    .unwrap_or_else(|_| b"ERR internal handler panic (contained)".to_vec()),
                };
                let quit = reply.as_slice() == b"OK bye";
                if codec::write_frame(&mut self.out, &reply).is_err() {
                    return false;
                }
                !quit
            }
        }
    }

    /// Transport-owned dispatch: `AUTH`, `METRICS`, and the auth gate.
    /// `None` hands the command to the application [`Handler`].
    fn transport_reply(
        &mut self,
        cfg: &ConnConfig,
        stats: &TransportStats,
        line: &str,
    ) -> Option<String> {
        let mut parts = line.split_whitespace();
        let verb = parts.next()?.to_ascii_uppercase();
        match verb.as_str() {
            "AUTH" => Some(match (&cfg.auth_token, parts.next()) {
                // open server: accept any preamble so clients can send
                // one unconditionally
                (None, _) => "OK auth".into(),
                (Some(want), Some(got)) if ct_eq(want.as_bytes(), got.as_bytes()) => {
                    self.session.authed = true;
                    "OK auth".into()
                }
                (Some(_), _) => {
                    crate::obs::events::emit(
                        crate::obs::Severity::Warn,
                        crate::obs::events::kind::AUTH_REJECT,
                        "",
                        "bad token on AUTH preamble",
                    );
                    err_reply(code::AUTH, "bad auth token")
                }
            }),
            "METRICS" => Some(match parts.next().map(|f| f.to_ascii_uppercase()) {
                // the bare reply line predates the registry and stays
                // byte-for-byte stable for existing scrapers
                None => stats.metrics_line(),
                Some(f) if f == "PROM" || f == "JSON" => {
                    stats.publish();
                    let reg = crate::obs::global();
                    let body = if f == "PROM" {
                        crate::obs::render_prom(reg)
                    } else {
                        crate::obs::render_json(reg)
                    };
                    let body = body.trim_end_matches('\n');
                    format!(
                        "OK metrics format={} lines={} bytes={}\n{body}",
                        f.to_ascii_lowercase(),
                        body.lines().count(),
                        body.len(),
                    )
                }
                Some(other) => format!("ERR unknown METRICS format {other} (want PROM or JSON)"),
            }),
            "TRACES" => {
                let n = parts
                    .next()
                    .and_then(|t| t.parse::<usize>().ok())
                    .unwrap_or(5);
                let traces = crate::obs::recent_traces(n);
                let lines: Vec<String> = traces.iter().flat_map(|t| t.render()).collect();
                let mut reply = format!("OK traces n={} lines={}", traces.len(), lines.len());
                for l in &lines {
                    reply.push('\n');
                    reply.push_str(l);
                }
                Some(reply)
            }
            "EVENTS" => {
                let n = parts
                    .next()
                    .and_then(|t| t.parse::<usize>().ok())
                    .unwrap_or(10);
                let min = parts.next().and_then(crate::obs::Severity::parse);
                let events = crate::obs::recent_events(n, min);
                let mut reply = format!("OK events n={} lines={}", events.len(), events.len());
                for e in &events {
                    reply.push('\n');
                    reply.push_str(&e.render());
                }
                Some(reply)
            }
            "HEALTH" => {
                let graph = parts.next();
                let rep = crate::obs::health::evaluate_global(graph);
                let mut reply = format!(
                    "OK health={} reasons={} lines={}",
                    rep.verdict.as_str(),
                    rep.reasons.len(),
                    rep.reasons.len()
                );
                for r in &rep.reasons {
                    reply.push('\n');
                    reply.push_str(r);
                }
                Some(reply)
            }
            v if cfg.auth_token.is_some() && !self.session.authed && AUTH_VERBS.contains(&v) => {
                crate::obs::events::emit(
                    crate::obs::Severity::Warn,
                    crate::obs::events::kind::AUTH_REJECT,
                    "",
                    format!("unauthenticated {v}"),
                );
                Some(err_reply(
                    code::AUTH,
                    format!("auth required for {v} (send AUTH <token> first)"),
                ))
            }
            _ => None,
        }
    }

    /// Resume (or start) reading one line. At most one socket timeout
    /// is absorbed per call — the caller yields on [`ReadStep::Pending`]
    /// and this picks the buffer back up next slice.
    fn read_line_step(&mut self, stall: Duration) -> std::io::Result<ReadStep<String>> {
        let mut line = match std::mem::replace(&mut self.partial, Partial::None) {
            Partial::None => {
                self.last_progress = Instant::now();
                Vec::new()
            }
            Partial::Line(l) => l,
            Partial::Frame(_) => unreachable!("line step with a frame partial"),
        };
        loop {
            let (upto, newline) = match self.reader.fill_buf() {
                Ok(buf) if buf.is_empty() => {
                    // EOF: hand back any trailing unterminated line
                    return Ok(if line.is_empty() {
                        ReadStep::Closed
                    } else {
                        ReadStep::Data(String::from_utf8_lossy(&line).into_owned())
                    });
                }
                Ok(buf) => {
                    let newline = buf.iter().position(|&b| b == b'\n');
                    let upto = newline.unwrap_or(buf.len());
                    if line.len() + upto > MAX_LINE_BYTES {
                        return Err(std::io::Error::new(
                            ErrorKind::InvalidData,
                            "protocol line too long",
                        ));
                    }
                    line.extend_from_slice(&buf[..upto]);
                    (upto, newline.is_some())
                }
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    if line.is_empty() {
                        return Ok(ReadStep::Idle);
                    }
                    if self.last_progress.elapsed() >= stall {
                        return Err(std::io::Error::new(
                            ErrorKind::TimedOut,
                            "line stalled mid-request",
                        ));
                    }
                    self.partial = Partial::Line(line);
                    return Ok(ReadStep::Pending);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            };
            self.reader.consume(if newline { upto + 1 } else { upto });
            self.last_progress = Instant::now();
            if newline {
                return Ok(ReadStep::Data(String::from_utf8_lossy(&line).into_owned()));
            }
        }
    }

    /// Resume (or start) reading one frame. At most one socket timeout
    /// is absorbed per call — the caller yields on [`ReadStep::Pending`]
    /// and this picks the header/body back up next slice.
    fn read_frame_step(&mut self, stall: Duration) -> std::io::Result<ReadStep<Vec<u8>>> {
        let mut st = match std::mem::replace(&mut self.partial, Partial::None) {
            Partial::None => {
                self.last_progress = Instant::now();
                FramePartial::fresh()
            }
            Partial::Frame(f) => f,
            Partial::Line(_) => unreachable!("frame step with a line partial"),
        };
        loop {
            if st.hfilled < st.header.len() {
                match self.reader.read(&mut st.header[st.hfilled..]) {
                    Ok(0) => {
                        return if st.hfilled == 0 {
                            Ok(ReadStep::Closed)
                        } else {
                            Err(std::io::Error::new(
                                ErrorKind::UnexpectedEof,
                                "connection closed mid-frame",
                            ))
                        };
                    }
                    Ok(n) => {
                        st.hfilled += n;
                        self.last_progress = Instant::now();
                        if st.hfilled == st.header.len() {
                            let len = u32::from_le_bytes(st.header) as usize;
                            if len > MAX_FRAME_BYTES {
                                return Err(std::io::Error::new(
                                    ErrorKind::InvalidData,
                                    "frame too large",
                                ));
                            }
                            st.body = Some(vec![0u8; len]);
                        }
                    }
                    Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                        if st.hfilled == 0 {
                            return Ok(ReadStep::Idle);
                        }
                        if self.last_progress.elapsed() >= stall {
                            return Err(std::io::Error::new(
                                ErrorKind::TimedOut,
                                "frame stalled mid-request",
                            ));
                        }
                        self.partial = Partial::Frame(st);
                        return Ok(ReadStep::Pending);
                    }
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(e) => return Err(e),
                }
                continue;
            }
            let at = st.bfilled;
            let body = st.body.as_mut().expect("allocated with the header");
            if at < body.len() {
                match self.reader.read(&mut body[at..]) {
                    Ok(0) => {
                        return Err(std::io::Error::new(
                            ErrorKind::UnexpectedEof,
                            "connection closed mid-frame",
                        ));
                    }
                    Ok(n) => {
                        st.bfilled += n;
                        self.last_progress = Instant::now();
                    }
                    Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                        if self.last_progress.elapsed() >= stall {
                            return Err(std::io::Error::new(
                                ErrorKind::TimedOut,
                                "frame stalled mid-request",
                            ));
                        }
                        self.partial = Partial::Frame(st);
                        return Ok(ReadStep::Pending);
                    }
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(e) => return Err(e),
                }
                continue;
            }
            return Ok(ReadStep::Data(st.body.take().expect("complete body")));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ct_eq_matches_plain_equality() {
        assert!(ct_eq(b"secret", b"secret"));
        assert!(!ct_eq(b"secret", b"secreT"));
        assert!(!ct_eq(b"secret", b"secre"));
        assert!(!ct_eq(b"", b"x"));
        assert!(ct_eq(b"", b""));
        // a length delta that is a multiple of 256 must still mismatch
        // (a u8-narrowed length fold would wrap to 0 and accept this)
        let mut padded = b"secret".to_vec();
        padded.extend(std::iter::repeat(0u8).take(256));
        assert!(!ct_eq(b"secret", &padded));
    }

    #[test]
    fn verb_tables_have_no_duplicates_and_cover_the_gate() {
        let mut all: Vec<&str> = LINE_VERBS.iter().chain(FRAME_VERBS).copied().collect();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "duplicate verb across the tables");
        for v in AUTH_VERBS {
            assert!(
                FRAME_VERBS.contains(v),
                "auth-gated verb {v} missing from FRAME_VERBS"
            );
        }
    }

    #[test]
    fn cluster_tables_are_consistent_and_err_replies_are_coded() {
        // every alias forwards an existing line verb to a real sub-verb
        for (old, sub) in CLUSTER_ALIASES {
            assert!(
                LINE_VERBS.contains(old),
                "alias source {old} is not a line verb"
            );
            assert!(
                CLUSTER_SUBVERBS.contains(sub),
                "alias target {sub} is not a CLUSTER sub-verb"
            );
        }
        // sub-verbs are unique (one dispatch table, no shadowing)
        let mut subs: Vec<&str> = CLUSTER_SUBVERBS.to_vec();
        subs.sort_unstable();
        subs.dedup();
        assert_eq!(subs.len(), CLUSTER_SUBVERBS.len(), "duplicate sub-verb");
        // the coded reply shape clients parse: `ERR <CODE> <msg>`
        assert_eq!(
            err_reply(code::STALE_EPOCH, "chain starts at epoch 7"),
            "ERR STALE_EPOCH chain starts at epoch 7"
        );
        assert!(code::ALL.contains(&code::MIGRATING));
        assert_eq!(code::ALL.len(), 7, "codes are append-only and stable");
    }

    #[test]
    fn publish_mirrors_transport_counters_into_the_registry() {
        let stats = TransportStats::default();
        stats.workers.store(3, Ordering::Relaxed);
        stats.accepted.fetch_add(11, Ordering::Relaxed);
        stats.publish();
        // the global registry is shared with concurrently running tests,
        // so assert the series exist rather than pin exact values
        let text = crate::obs::render_prom(crate::obs::global());
        for series in ["pico_net_workers", "pico_net_accepted_total", "pico_net_conn_cap"] {
            assert!(text.contains(series), "missing {series} in:\n{text}");
        }
    }

    #[test]
    fn metrics_line_is_structured() {
        let stats = TransportStats::default();
        stats.workers.store(4, Ordering::Relaxed);
        stats.accepted.fetch_add(7, Ordering::Relaxed);
        stats.write_stalled.fetch_add(2, Ordering::Relaxed);
        let line = stats.metrics_line();
        assert!(line.starts_with("OK workers=4 "), "{line}");
        assert!(line.contains(" accepted=7 "), "{line}");
        assert!(line.contains(" timed_out=0"), "{line}");
        assert!(line.contains(" write_stalled=2"), "{line}");
    }

    /// A sink that accepts a fixed number of bytes per call, then
    /// turns `WouldBlock` — the shape of a peer with a full socket
    /// buffer.
    struct Trickle {
        taken: Vec<u8>,
        per_call: usize,
        budget: usize,
    }

    impl Write for Trickle {
        fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
            if self.budget == 0 {
                return Err(std::io::Error::from(ErrorKind::WouldBlock));
            }
            let n = data.len().min(self.per_call).min(self.budget);
            self.taken.extend_from_slice(&data[..n]);
            self.budget -= n;
            Ok(n)
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn outbuf_stages_flushes_partially_and_resumes() {
        let mut out = OutBuf::new();
        writeln!(out, "OK first").unwrap();
        writeln!(out, "OK second").unwrap();
        let staged = out.pending();
        assert_eq!(staged, "OK first\nOK second\n".len());

        // the peer takes 4 bytes per write and 10 in total, then blocks
        let mut sink = Trickle {
            taken: Vec::new(),
            per_call: 4,
            budget: 10,
        };
        out.flush_to(&mut sink).unwrap();
        assert_eq!(sink.taken, b"OK first\nO");
        assert_eq!(out.pending(), staged - 10, "partial flush is resumable");

        // the peer drains; the rest goes out and the buffer resets
        sink.budget = usize::MAX;
        out.flush_to(&mut sink).unwrap();
        assert_eq!(sink.taken, b"OK first\nOK second\n");
        assert_eq!(out.pending(), 0);
        assert_eq!(out.buf.len(), 0, "fully flushed buffer is released");
    }

    #[test]
    fn outbuf_write_frame_stays_single_site() {
        // frames stage through the same codec primitive the blocking
        // path used, so framing cannot drift between code paths
        let mut out = OutBuf::new();
        codec::write_frame(&mut out, b"OK pong").unwrap();
        let mut sink = Trickle {
            taken: Vec::new(),
            per_call: usize::MAX,
            budget: usize::MAX,
        };
        out.flush_to(&mut sink).unwrap();
        let mut r = std::io::Cursor::new(sink.taken);
        let body = codec::read_frame(&mut r, 1024).unwrap().unwrap();
        assert_eq!(body, b"OK pong");
    }

    #[test]
    fn events_and_health_are_transport_verbs() {
        let cfg = ConnConfig::default();
        let stats = TransportStats::default();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let _peer = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let mut conn = Connection::new(stream, "g".into(), 0).unwrap();

        crate::obs::events::emit(
            crate::obs::Severity::Warn,
            crate::obs::events::kind::REPLICA_FAILOVER,
            "conn-test",
            "replica=127.0.0.1:1 err=dial",
        );
        let reply = conn.transport_reply(&cfg, &stats, "EVENTS 500").unwrap();
        let head = reply.lines().next().unwrap();
        assert!(head.starts_with("OK events n="), "{head}");
        assert!(
            reply
                .lines()
                .skip(1)
                .any(|l| l.contains("replica_failover") && l.contains("graph=conn-test")),
            "{reply}"
        );

        // the min-severity filter drops anything below it
        let reply = conn.transport_reply(&cfg, &stats, "EVENTS 500 error").unwrap();
        assert!(
            reply
                .lines()
                .skip(1)
                .all(|l| l.split_whitespace().nth(1) == Some("error")),
            "{reply}"
        );

        // HEALTH answers a parseable verdict even for an unknown graph
        // (the global registry is shared with concurrent tests, so the
        // verdict itself is not pinned here)
        let reply = conn
            .transport_reply(&cfg, &stats, "HEALTH no-such-graph")
            .unwrap();
        let head = reply.lines().next().unwrap();
        let verdict = head
            .strip_prefix("OK health=")
            .and_then(|r| r.split_whitespace().next())
            .unwrap_or("");
        assert!(
            crate::obs::health::Verdict::parse(verdict).is_some(),
            "unparseable HEALTH head: {head}"
        );
    }

    #[test]
    fn deadlines_and_interest_track_connection_state() {
        let cfg = ConnConfig::default();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let _peer = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let mut conn = Connection::new(stream, "g".into(), 0).unwrap();

        // boundary-idle off the cap: parked indefinitely, read-only
        assert!(conn.at_boundary() && conn.drain_closable());
        assert_eq!(conn.next_deadline(&cfg, false), None);
        assert_eq!(conn.poll_interest(&cfg), (true, false));

        // at the cap the idle-reclaim clock arms
        assert!(conn.next_deadline(&cfg, true).is_some());

        // staged output adds write interest and a write-stall deadline
        writeln!(conn.out, "OK reply").unwrap();
        assert_eq!(conn.poll_interest(&cfg), (true, true));
        assert!(!conn.drain_closable());
        let stall = conn.next_deadline(&cfg, false).expect("write deadline");
        assert!(stall <= Instant::now() + cfg.stall_timeout);

        // over the high-water mark, read interest drops (backpressure)
        conn.out.buf = vec![b'x'; cfg.out_hwm + 2];
        conn.out.pos = 0;
        assert_eq!(conn.poll_interest(&cfg), (false, true));

        // mid-request, the read-stall deadline arms
        conn.out = OutBuf::new();
        conn.partial = Partial::Line(b"PIN".to_vec());
        assert!(!conn.at_boundary());
        assert!(conn.next_deadline(&cfg, false).is_some());
    }
}
