//! The unified transport layer: every byte that crosses a pico socket
//! goes through this module.
//!
//! Before this layer existed, the frame/line codec was re-implemented
//! in five places (server, remote shard, cluster wire, snapshot
//! shipping, CLI) and the server spawned one unbounded OS thread per
//! accepted connection. Following the project's own thesis —
//! restructure the synchronization skeleton so the same work costs
//! less — the wire plumbing now has one home:
//!
//! * [`codec`] — the single source of truth for the line protocol
//!   limits, the length-prefixed binary framing, every payload magic,
//!   and the bounds-checked [`codec::Cursor`] all untrusted payload
//!   decoders share.
//! * [`conn`] — the per-connection session state machine (line mode,
//!   `BINARY` upgrade, graph pinning, `AUTH` gating of the shard
//!   verbs, `METRICS`, drain awareness, slow-loris timeouts, and the
//!   bounded outbound buffer with write backpressure), delegating
//!   application verbs through the [`conn::Handler`] trait.
//! * [`pool`] — the bounded server: one accept thread and a fixed
//!   worker pool over a connection run queue, with a hard connection
//!   cap and accepted/active/queued/rejected/timed-out/write-stalled
//!   counters.
//! * [`poller`] — the readiness thread: every parked (idle) connection
//!   waits in one raw `poll(2)` set and reaches a worker only when its
//!   socket turns readable/writable or a deadline expires, so idle
//!   connections cost the pool nothing per poll interval.
//! * [`client`] — the one reconnecting protocol client shared by the
//!   remote-shard backend, `pico query` (including one-hop cluster
//!   redirects), and `pico cluster status`.
//!
//! The application protocol itself (verb semantics, backends, the
//! multi-graph service) stays in [`crate::service::server`], which
//! implements [`conn::Handler`].

pub mod client;
pub mod codec;
pub mod conn;
pub mod poller;
pub mod pool;

pub use client::{follow_redirect, parse_redirect, Client, FrameClient, Redirect};
pub use codec::{
    read_frame, split_frame, write_frame, Cursor, MAX_FRAME_BYTES, MAX_LINE_BYTES,
};
pub use conn::{env_auth_token, ConnConfig, Handler, Session, TransportStats};
pub use poller::raise_nofile_limit;
pub use pool::{default_workers, serve_handler, NetConfig, ServerHandle};
