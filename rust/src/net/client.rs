//! The one protocol client everything dials through: `pico query`,
//! `pico cluster status`, and the remote-shard backend
//! ([`crate::cluster::remote::RemoteShard`]) all share this module
//! instead of hand-rolling three dialers.
//!
//! Two layers:
//!
//! * [`Client`] — one live connection: line mode after connect, binary
//!   frame mode after [`Client::upgrade_binary`], optional `AUTH`
//!   preamble, `USE` graph pinning, and redirect parsing
//!   ([`parse_redirect`] / [`follow_redirect`]) for cluster
//!   coordinators that answer a shard-local probe with the owning
//!   shard host's address.
//! * [`FrameClient`] — a reconnecting binary-frame client: a sticky
//!   connection with explicit graph pinning that re-dials once when a
//!   pooled connection has gone stale between calls. Replay is the
//!   caller's decision per verb: [`FrameClient::call_idempotent`]
//!   retries a lost reply, [`FrameClient::call_once`] never does (the
//!   distinction the shard protocol's mutation verbs depend on — see
//!   [`crate::cluster::remote`]).

use super::codec::{read_frame, split_frame, write_frame, MAX_FRAME_BYTES};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::Mutex;
use std::time::Duration;

/// Dial timeout for every connect in this module — a dead host must
/// fail over quickly, and a CLI probe must not hang.
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(2);

/// Dial attempts one logical [`FrameClient`] connect gets before the
/// error surfaces. A remote shard host mid-restart answers
/// `ECONNREFUSED` for a few tens of milliseconds — without a retry,
/// the first probe after every host restart fails spuriously.
const CONNECT_ATTEMPTS: u32 = 3;

/// Pause before dial attempt `n` (linear: 25ms, 50ms). Deliberately
/// small and bounded: anything down for longer than this should
/// surface as an error to the caller's own failover policy, not hide
/// inside the transport.
const CONNECT_BACKOFF: Duration = Duration::from_millis(25);

/// `key=value` token lookup in a reply head line.
pub fn field<'a>(head: &'a str, key: &str) -> Result<&'a str> {
    head.split_whitespace()
        .find_map(|tok| tok.strip_prefix(key).and_then(|r| r.strip_prefix('=')))
        .ok_or_else(|| anyhow!("missing {key}= in reply '{head}'"))
}

pub fn field_u64(head: &str, key: &str) -> Result<u64> {
    field(head, key)?
        .parse::<u64>()
        .with_context(|| format!("bad {key}= in reply '{head}'"))
}

/// A stable machine-readable error code parsed off an `ERR <CODE> <msg>`
/// reply — mirrors the server-side table in [`crate::net::conn::code`].
/// Retry/failover policy keys off this instead of string-matching the
/// free-text tail.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrCode {
    /// Missing/wrong `AUTH <token>` preamble.
    Auth,
    /// No such graph (or none selected).
    NoGraph,
    /// Epoch fence: the request's epoch does not match the shard's.
    StaleEpoch,
    /// The answer lives on another host.
    Redirect,
    /// A server-side limit was hit (caps, queues).
    Capacity,
    /// Malformed request.
    BadReq,
    /// A rebalance is in flight; retry after it completes.
    Migrating,
}

impl ErrCode {
    /// Parse the code token (second word of an `ERR <CODE> <msg>`
    /// reply). Unknown tokens are `None` — old servers answer plain
    /// `ERR <msg>` and that must stay a valid, merely uncoded, error.
    pub fn parse(tok: &str) -> Option<Self> {
        Some(match tok {
            "AUTH" => Self::Auth,
            "NOGRAPH" => Self::NoGraph,
            "STALE_EPOCH" => Self::StaleEpoch,
            "REDIRECT" => Self::Redirect,
            "CAPACITY" => Self::Capacity,
            "BADREQ" => Self::BadReq,
            "MIGRATING" => Self::Migrating,
            _ => return None,
        })
    }
}

/// A remote `ERR` reply carried as a typed error: the full head line
/// for humans, the parsed [`ErrCode`] (if the server sent one) for
/// policy. Display stays `remote: <head>` so existing error text is
/// unchanged; callers that need the code reach it through
/// [`remote_err_code`] instead of matching substrings.
#[derive(Debug)]
pub struct RemoteReplyError {
    pub code: Option<ErrCode>,
    pub head: String,
}

impl RemoteReplyError {
    fn from_head(head: String) -> Self {
        let code = head
            .strip_prefix("ERR ")
            .and_then(|rest| rest.split_whitespace().next())
            .and_then(ErrCode::parse);
        Self { code, head }
    }
}

impl std::fmt::Display for RemoteReplyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "remote: {}", self.head)
    }
}

impl std::error::Error for RemoteReplyError {}

/// The [`ErrCode`] buried in an error chain, if the failure was a coded
/// remote `ERR` reply (transport failures and uncoded `ERR`s are
/// `None`).
pub fn remote_err_code(e: &anyhow::Error) -> Option<ErrCode> {
    e.chain()
        .find_map(|c| c.downcast_ref::<RemoteReplyError>())
        .and_then(|r| r.code)
}

/// Split a reply frame into its head line and raw payload; `ERR` heads
/// become [`RemoteReplyError`]s (code parsed, text preserved).
pub fn split_reply(frame: Vec<u8>) -> Result<(String, Vec<u8>)> {
    let (head, payload) = split_frame(&frame);
    let head = std::str::from_utf8(head)
        .context("reply head not UTF-8")?
        .to_string();
    let payload = payload.to_vec();
    if head.starts_with("ERR") {
        return Err(RemoteReplyError::from_head(head).into());
    }
    Ok((head, payload))
}

/// A one-hop redirect target parsed from a coordinator reply.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Redirect {
    pub addr: String,
    pub graph: String,
}

/// Parse a `REDIRECT shard=<s> addr=<host:port> graph=<name>` reply
/// line (the cluster coordinator's answer to a shard-local probe whose
/// shard lives on another host). `None` for every other reply.
pub fn parse_redirect(reply: &str) -> Option<Redirect> {
    let rest = reply.strip_prefix("REDIRECT ")?;
    Some(Redirect {
        addr: field(rest, "addr").ok()?.to_string(),
        graph: field(rest, "graph").ok()?.to_string(),
    })
}

/// Follow one redirect hop: dial the named shard host, pin its graph,
/// re-send the command, and return the remote reply. One hop max — a
/// redirect answering a redirect is an error, never a loop.
pub fn follow_redirect(rd: &Redirect, cmd: &str, auth: Option<&str>) -> Result<String> {
    let mut c = Client::connect(&rd.addr)
        .with_context(|| format!("following redirect to {}", rd.addr))?;
    if let Some(token) = auth {
        c.auth(token)?;
    }
    c.use_graph(&rd.graph)?;
    let reply = c.send_line(cmd)?;
    if parse_redirect(&reply).is_some() {
        bail!("{} answered the redirected '{cmd}' with another redirect", rd.addr);
    }
    Ok(reply)
}

/// One live protocol connection (line mode until upgraded).
pub struct Client {
    addr: String,
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    binary: bool,
}

impl Client {
    /// Dial `addr` (within [`CONNECT_TIMEOUT`]); the session starts in
    /// line mode on the server's default graph.
    pub fn connect(addr: &str) -> Result<Self> {
        let sockaddr = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr}"))?
            .next()
            .with_context(|| format!("{addr} resolves to no address"))?;
        let stream = TcpStream::connect_timeout(&sockaddr, CONNECT_TIMEOUT)
            .with_context(|| format!("connecting to pico serve at {addr}"))?;
        let writer = stream.try_clone().context("cloning the connection")?;
        Ok(Self {
            addr: addr.to_string(),
            writer,
            reader: BufReader::new(stream),
            binary: false,
        })
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn is_binary(&self) -> bool {
        self.binary
    }

    /// Send one line-mode command and read its reply line. `ERR`
    /// replies are returned, not raised — line mode is the CLI surface
    /// and the caller decides what a rejection means.
    pub fn send_line(&mut self, cmd: &str) -> Result<String> {
        assert!(!self.binary, "send_line on an upgraded connection");
        writeln!(self.writer, "{cmd}")?;
        self.writer.flush()?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            bail!("{} closed the connection after '{cmd}'", self.addr);
        }
        Ok(line.trim_end().to_string())
    }

    /// Send one line-mode command whose reply spans multiple lines: the
    /// head line declares `lines=<n>` and exactly `n` body lines follow
    /// (`METRICS PROM|JSON`, `TRACES`). `ERR` heads are raised so the
    /// caller never desyncs the stream guessing at a body.
    pub fn send_multiline(&mut self, cmd: &str) -> Result<(String, Vec<String>)> {
        let head = self.send_line(cmd)?;
        if head.starts_with("ERR") {
            bail!("{}: {head}", self.addr);
        }
        let n = field_u64(&head, "lines")? as usize;
        let mut body = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                bail!("{} closed mid multi-line reply to '{cmd}'", self.addr);
            }
            body.push(line.trim_end().to_string());
        }
        Ok((head, body))
    }

    /// Upgrade to binary framing (`BINARY` handshake).
    pub fn upgrade_binary(&mut self) -> Result<()> {
        let reply = self.send_line("BINARY").context("binary upgrade")?;
        if !reply.starts_with("OK binary") {
            bail!("{} refused the binary upgrade: {reply}", self.addr);
        }
        self.binary = true;
        Ok(())
    }

    /// Authenticate the connection for the shard verbs (`AUTH`
    /// preamble; works in both modes, a no-op reply on open servers).
    pub fn auth(&mut self, token: &str) -> Result<()> {
        let reply = if self.binary {
            let (head, _) = split_reply(self.call_raw(format!("AUTH {token}").as_bytes())?)?;
            head
        } else {
            self.send_line(&format!("AUTH {token}"))?
        };
        if !reply.starts_with("OK auth") {
            bail!("{} rejected the auth token: {reply}", self.addr);
        }
        Ok(())
    }

    /// Pin the session to `graph` (`USE`); an unhosted graph is an
    /// error, not a silent fall-through to the server's default.
    pub fn use_graph(&mut self, graph: &str) -> Result<()> {
        let reply = if self.binary {
            String::from_utf8_lossy(&self.call_raw(format!("USE {graph}").as_bytes())?)
                .into_owned()
        } else {
            self.send_line(&format!("USE {graph}"))?
        };
        if !reply.starts_with("OK") {
            bail!(
                "{}: graph '{graph}' is not hosted ({})",
                self.addr,
                reply.trim_end()
            );
        }
        Ok(())
    }

    /// One binary frame out, one back (raw body, `ERR` not inspected).
    pub fn call_raw(&mut self, body: &[u8]) -> Result<Vec<u8>> {
        assert!(self.binary, "call_raw before the binary upgrade");
        if body.len() > MAX_FRAME_BYTES {
            bail!(
                "request frame is {} bytes, above the cap ({MAX_FRAME_BYTES})",
                body.len()
            );
        }
        write_frame(&mut self.writer, body)?;
        read_frame(&mut self.reader, MAX_FRAME_BYTES)?
            .ok_or_else(|| anyhow!("connection closed mid-reply"))
    }

    /// One frame round trip, reply split into `(head, payload)` with
    /// `ERR` heads raised.
    pub fn call(&mut self, body: &[u8]) -> Result<(String, Vec<u8>)> {
        split_reply(self.call_raw(body)?)
    }

    /// Best-effort goodbye (`QUIT`) — for CLI sessions that want the
    /// server, not a RST, to close the connection.
    pub fn quit(mut self) {
        if self.binary {
            let _ = write_frame(&mut self.writer, b"QUIT");
        } else {
            let _ = writeln!(self.writer, "QUIT");
        }
    }
}

/// A sticky, reconnecting binary-frame connection pinned to one hosted
/// graph on one server.
struct PinnedConn {
    client: Client,
    /// Whether the server session is pinned to `graph`. Until `USE`
    /// succeeds (or `SHARDHOST` installs the graph), pinned verbs must
    /// NOT be sent — the server session would fall back to its default
    /// graph and silently answer for the wrong one.
    selected: bool,
}

/// The reconnecting frame client shared by every long-lived dialer.
///
/// A connection that dies between calls is re-dialed once — but a lost
/// reply is replayed only through [`FrameClient::call_idempotent`];
/// verbs that mutate remote state go through [`FrameClient::call_once`]
/// and surface the error instead. Dialing itself gets a small bounded
/// backoff (a restarting host refuses connections for a few tens of
/// milliseconds), but a *request* that fails on a just-dialed socket
/// is never retried — the host is down and the caller needs to know
/// now.
pub struct FrameClient {
    addr: String,
    graph: String,
    auth: Option<String>,
    conn: Mutex<Option<PinnedConn>>,
}

impl FrameClient {
    /// A client for the hosted graph `graph` on the server at `addr`.
    pub fn new(addr: impl Into<String>, graph: impl Into<String>) -> Self {
        Self {
            addr: addr.into(),
            graph: graph.into(),
            auth: None,
            conn: Mutex::new(None),
        }
    }

    /// Send `AUTH <token>` on every (re)connect — required whenever the
    /// far server gates its shard verbs.
    pub fn with_auth(mut self, token: Option<String>) -> Self {
        self.auth = token;
        self
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn graph(&self) -> &str {
        &self.graph
    }

    /// Dial with a small bounded backoff ([`CONNECT_ATTEMPTS`] tries,
    /// [`CONNECT_BACKOFF`] apart): a host mid-restart gets a moment to
    /// finish binding before the error surfaces. The whole handshake
    /// (dial, `BINARY` upgrade, `AUTH`) is retried — none of it sends
    /// application state, so replaying it is always safe.
    fn connect(&self) -> Result<PinnedConn> {
        let mut last_err = None;
        for attempt in 0..CONNECT_ATTEMPTS {
            if attempt > 0 {
                std::thread::sleep(CONNECT_BACKOFF * attempt);
            }
            match self.connect_once() {
                Ok(conn) => return Ok(conn),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.expect("at least one connect attempt"))
    }

    fn connect_once(&self) -> Result<PinnedConn> {
        let mut client =
            Client::connect(&self.addr).with_context(|| format!("dialing {}", self.addr))?;
        client.upgrade_binary()?;
        if let Some(token) = &self.auth {
            client.auth(token)?;
        }
        Ok(PinnedConn {
            client,
            selected: false,
        })
    }

    /// Pin the server session to this client's graph if it isn't yet.
    fn ensure_selected(&self, conn: &mut PinnedConn) -> Result<()> {
        if conn.selected {
            return Ok(());
        }
        conn.client
            .use_graph(&self.graph)
            .with_context(|| format!("pinning shard graph on {}", self.addr))?;
        conn.selected = true;
        Ok(())
    }

    fn exchange(&self, conn: &mut PinnedConn, body: &[u8], select: bool) -> Result<Vec<u8>> {
        if select {
            self.ensure_selected(conn)?;
        }
        conn.client.call_raw(body)
    }

    /// One frame round trip; a stale pooled connection gets one
    /// re-dial. With `select`, the session is pinned to the graph
    /// first. `retry` must only be true for idempotent verbs: a
    /// retried request may have already executed once (lost reply).
    fn call_with(&self, body: &[u8], select: bool, retry: bool) -> Result<Vec<u8>> {
        let mut guard = self.conn.lock().unwrap();
        let had_conn = guard.is_some();
        if guard.is_none() {
            *guard = Some(self.connect()?);
        }
        let first = self.exchange(guard.as_mut().unwrap(), body, select);
        match first {
            Ok(reply) => Ok(reply),
            Err(_) if had_conn && retry => {
                // the pooled connection went stale between calls
                *guard = None;
                *guard = Some(self.connect()?);
                match self.exchange(guard.as_mut().unwrap(), body, select) {
                    Ok(reply) => Ok(reply),
                    Err(e) => {
                        *guard = None;
                        Err(e)
                    }
                }
            }
            Err(e) => {
                *guard = None;
                Err(e)
            }
        }
    }

    /// Idempotent request (probes, reads, installs that reproduce the
    /// same state): safe to replay after a lost reply. With `select`
    /// the session is pinned to the graph first.
    pub fn call_idempotent(&self, body: &[u8], select: bool) -> Result<(String, Vec<u8>)> {
        split_reply(self.call_with(body, select, true)?)
    }

    /// Non-idempotent request: never replayed after a lost reply; the
    /// error surfaces to the caller instead.
    pub fn call_once(&self, body: &[u8], select: bool) -> Result<(String, Vec<u8>)> {
        split_reply(self.call_with(body, select, false)?)
    }

    /// Mark the pooled connection's session as pinned (after a
    /// successful `SHARDHOST`, the server selects the new graph
    /// itself).
    pub fn mark_selected(&self) {
        if let Some(conn) = self.conn.lock().unwrap().as_mut() {
            conn.selected = true;
        }
    }
}

impl std::fmt::Debug for FrameClient {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "FrameClient({} '{}')", self.addr, self.graph)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reply_fields_parse() {
        let head = "OK shard=3 epoch=9 cluster=2 owned=100 kmax=7";
        assert_eq!(field(head, "shard").unwrap(), "3");
        assert_eq!(field_u64(head, "owned").unwrap(), 100);
        assert!(field(head, "missing").is_err());
        // prefix keys must not match longer tokens
        assert!(field("OK clusterx=5", "cluster").is_err());
    }

    #[test]
    fn err_replies_become_errors() {
        assert!(split_reply(b"ERR nope".to_vec()).is_err());
        let (head, payload) = split_reply(b"OK x=1\nabc".to_vec()).unwrap();
        assert_eq!(head, "OK x=1");
        assert_eq!(payload, b"abc");
    }

    #[test]
    fn coded_err_replies_carry_a_parsed_code() {
        let e = split_reply(b"ERR STALE_EPOCH chain starts at epoch 7".to_vec()).unwrap_err();
        assert_eq!(remote_err_code(&e), Some(ErrCode::StaleEpoch));
        // the human-facing text is unchanged by the typed carrier
        assert_eq!(
            format!("{e:#}"),
            "remote: ERR STALE_EPOCH chain starts at epoch 7"
        );
        // uncoded (legacy) and unknown-code replies stay plain errors
        let e = split_reply(b"ERR something broke".to_vec()).unwrap_err();
        assert_eq!(remote_err_code(&e), None);
        let e = split_reply(b"ERR WAT new-server code".to_vec()).unwrap_err();
        assert_eq!(remote_err_code(&e), None);
        // a context wrapper must not hide the code from the extractor
        let e = split_reply(b"ERR MIGRATING rebalance in flight".to_vec())
            .unwrap_err()
            .context("probing shard 2");
        assert_eq!(remote_err_code(&e), Some(ErrCode::Migrating));
        // transport errors carry no code
        assert_eq!(remote_err_code(&anyhow!("connection reset")), None);
    }

    #[test]
    fn redirects_parse_and_reject_noise() {
        let rd = parse_redirect("REDIRECT shard=1 addr=10.0.0.7:7571 graph=soc/shard1").unwrap();
        assert_eq!(rd.addr, "10.0.0.7:7571");
        assert_eq!(rd.graph, "soc/shard1");
        assert!(parse_redirect("OK core=3 epoch=1").is_none());
        assert!(parse_redirect("REDIRECT addr=onlyaddr:1").is_none(), "graph missing");
        assert!(parse_redirect("ERR nope").is_none());
    }

    #[test]
    fn dead_host_fails_fast() {
        // reserved port: nothing listens; the dial must fail, not hang
        assert!(Client::connect("127.0.0.1:1").is_err());
        let fc = FrameClient::new("127.0.0.1:1", "x/shard0");
        assert!(fc.call_idempotent(b"PING", false).is_err());
    }
}
