//! The readiness side of the bounded transport: one event thread
//! watching every *parked* connection's socket with raw `poll(2)`, so
//! workers only ever touch connections that have something to do.
//!
//! The PR 5 pool discovered readiness by rotating every live connection
//! through the run queue and letting a worker eat a read timeout on
//! each idle one — O(live) wasted wakeups per poll interval on a
//! mostly-idle fleet. Here a worker hands an idle connection
//! ([`crate::net::conn::Slice::Park`]) to the [`Poller`], whose event
//! loop waits on *all* parked fds in one `poll(2)` call and feeds a
//! connection back to the run queue only when
//!
//! * its socket turns readable (a new request, or EOF),
//! * its socket turns writable while staged output is pending
//!   (backpressure flush), or
//! * a deadline expires — read-stall, write-stall, or at-cap idle
//!   reclaim; the worker re-runs the connection and the state machine
//!   in [`crate::net::conn`] decides which of those it was (and sends
//!   the structured `ERR`).
//!
//! The syscall surface is declared locally (`poll`, `pipe`, `fcntl`,
//! `getrlimit`) — no new dependencies — and gated on `cfg(unix)`;
//! elsewhere the poller degrades to the old timed rotation, so the
//! crate still builds and serves correctly, just without the
//! idle-fleet economics.

use super::conn::{ConnConfig, Connection, TransportStats};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Minimal hand-declared bindings for the handful of syscalls the
/// readiness loop needs. Kept local on purpose: the crate carries no
/// libc dependency, and one screen of `extern "C"` beats pulling one
/// in for four functions with identical layouts across the unixes we
/// target.
#[cfg(unix)]
pub(crate) mod sys {
    use std::os::raw::{c_int, c_ulong};
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;

    /// `struct pollfd` — identical layout on every supported unix.
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: i16,
        pub revents: i16,
    }

    impl PollFd {
        pub fn new(fd: RawFd, events: i16) -> Self {
            Self {
                fd,
                events,
                revents: 0,
            }
        }

        /// Readable, writable, error, or hangup — anything that makes
        /// the next non-blocking read/write on this fd return
        /// immediately instead of `WouldBlock`.
        pub fn ready(&self) -> bool {
            self.revents != 0
        }
    }

    #[cfg(target_os = "linux")]
    type NfdsT = c_ulong;
    #[cfg(not(target_os = "linux"))]
    type NfdsT = std::os::raw::c_uint;

    const F_GETFL: c_int = 3;
    const F_SETFL: c_int = 4;
    #[cfg(target_os = "linux")]
    const O_NONBLOCK: c_int = 0o4000;
    #[cfg(not(target_os = "linux"))]
    const O_NONBLOCK: c_int = 0x0004;
    #[cfg(target_os = "linux")]
    const RLIMIT_NOFILE: c_int = 7;
    #[cfg(not(target_os = "linux"))]
    const RLIMIT_NOFILE: c_int = 8;

    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
        fn pipe(fds: *mut c_int) -> c_int;
        fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
        fn read(fd: c_int, buf: *mut u8, count: usize) -> isize;
        fn write(fd: c_int, buf: *const u8, count: usize) -> isize;
        fn close(fd: c_int) -> c_int;
        fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
        fn setrlimit(resource: c_int, rlim: *const RLimit) -> c_int;
    }

    /// Wait on a set of fds; returns how many turned ready (0 on
    /// timeout or `EINTR` — callers re-check their state and loop
    /// either way, so the two need no distinction).
    pub fn poll_fds(fds: &mut [PollFd], timeout: Duration) -> usize {
        let ms = timeout.as_millis().min(i32::MAX as u128) as c_int;
        let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, ms) };
        n.max(0) as usize
    }

    /// Wait for one fd; `true` when it turned ready within `timeout`.
    pub fn poll_one(fd: RawFd, events: i16, timeout: Duration) -> bool {
        let mut fds = [PollFd::new(fd, events)];
        poll_fds(&mut fds, timeout) > 0 && fds[0].ready()
    }

    /// The classic self-pipe: [`WakePipe::wake`] makes a blocked
    /// [`poll_fds`] that includes [`WakePipe::read_fd`] return
    /// immediately, from any thread. Both ends are non-blocking, so a
    /// full pipe cannot stall a waker and a drained pipe cannot stall
    /// the event loop.
    pub struct WakePipe {
        rx: RawFd,
        tx: RawFd,
    }

    impl WakePipe {
        pub fn new() -> std::io::Result<Self> {
            let mut fds = [0 as c_int; 2];
            if unsafe { pipe(fds.as_mut_ptr()) } != 0 {
                return Err(std::io::Error::last_os_error());
            }
            for fd in fds {
                let flags = unsafe { fcntl(fd, F_GETFL, 0) };
                if flags < 0 || unsafe { fcntl(fd, F_SETFL, flags | O_NONBLOCK) } < 0 {
                    let err = std::io::Error::last_os_error();
                    unsafe {
                        close(fds[0]);
                        close(fds[1]);
                    }
                    return Err(err);
                }
            }
            Ok(Self {
                rx: fds[0],
                tx: fds[1],
            })
        }

        pub fn read_fd(&self) -> RawFd {
            self.rx
        }

        /// One byte down the pipe. A full pipe already wakes the
        /// poller, so `EAGAIN` is success here.
        pub fn wake(&self) {
            unsafe {
                write(self.tx, [1u8].as_ptr(), 1);
            }
        }

        /// Swallow every pending wake byte (called once a poll returns
        /// with the pipe readable).
        pub fn drain(&self) {
            let mut buf = [0u8; 64];
            while unsafe { read(self.rx, buf.as_mut_ptr(), buf.len()) } > 0 {}
        }
    }

    impl Drop for WakePipe {
        fn drop(&mut self) {
            unsafe {
                close(self.rx);
                close(self.tx);
            }
        }
    }

    /// Raise the soft `RLIMIT_NOFILE` toward `want` (capped at the
    /// hard limit) and return the resulting soft limit. The idle-fleet
    /// bench holds tens of thousands of sockets and sizes its fleet to
    /// whatever this achieves instead of dying on `EMFILE`.
    pub fn raise_nofile_limit(want: u64) -> u64 {
        unsafe {
            let mut r = RLimit { cur: 0, max: 0 };
            if getrlimit(RLIMIT_NOFILE, &mut r) != 0 {
                return 0;
            }
            if r.cur < want {
                let bumped = RLimit {
                    cur: want.min(r.max),
                    max: r.max,
                };
                if setrlimit(RLIMIT_NOFILE, &bumped) == 0 {
                    r.cur = bumped.cur;
                }
            }
            r.cur
        }
    }
}

#[cfg(unix)]
pub use sys::raise_nofile_limit;

/// Portability stub: no rlimit syscalls to raise — report 0 so callers
/// size their fleets down.
#[cfg(not(unix))]
pub fn raise_nofile_limit(_want: u64) -> u64 {
    0
}

/// Everything the event loop needs from the pool that spawned it.
pub struct PollerCtx {
    /// The per-connection transport knobs (deadlines, backpressure
    /// high-water mark, and the poll tick via
    /// [`ConnConfig::poll_timeout`]).
    pub cfg: ConnConfig,
    /// The pool's connection cap — at-cap is when idle reclaim arms.
    pub cap: usize,
    pub stats: Arc<TransportStats>,
    pub draining: Arc<AtomicBool>,
    pub hard_stop: Arc<AtomicBool>,
    /// Feeds a runnable connection back to the pool's run queue.
    pub enqueue: Box<dyn Fn(Connection) + Send>,
}

/// The shared handle to the readiness thread: workers park idle
/// connections here ([`Poller::park`]) and the event loop
/// ([`Poller::run`], one thread per server) watches them.
pub struct Poller {
    inbox: Mutex<Vec<Connection>>,
    #[cfg(unix)]
    wake: sys::WakePipe,
}

impl Poller {
    pub fn new() -> std::io::Result<Arc<Self>> {
        Ok(Arc::new(Self {
            inbox: Mutex::new(Vec::new()),
            #[cfg(unix)]
            wake: sys::WakePipe::new()?,
        }))
    }

    /// Hand an idle connection to the event thread. The wake matters:
    /// without it, a freshly parked connection would sit unwatched
    /// until the in-flight `poll` ticks over.
    pub fn park(&self, conn: Connection) {
        self.inbox.lock().unwrap().push(conn);
        self.wake();
    }

    /// Kick the event loop out of its current `poll` (used on park,
    /// drain, and shutdown).
    pub fn wake(&self) {
        #[cfg(unix)]
        self.wake.wake();
    }

    /// The event loop. Runs on its own thread until `ctx.hard_stop`;
    /// on hard stop every parked connection is dropped (closing its
    /// socket) and the live gauge is settled.
    pub fn run(&self, ctx: PollerCtx) {
        let mut parked: Vec<Connection> = Vec::new();
        #[cfg(unix)]
        let mut fds: Vec<sys::PollFd> = Vec::new();
        let tick = ctx.cfg.poll_timeout.max(Duration::from_millis(1));
        loop {
            if ctx.hard_stop.load(Ordering::SeqCst) {
                parked.append(&mut self.inbox.lock().unwrap());
                for conn in parked.drain(..) {
                    ctx.stats.active.fetch_sub(1, Ordering::SeqCst);
                    drop(conn);
                }
                return;
            }
            parked.append(&mut self.inbox.lock().unwrap());
            let draining = ctx.draining.load(Ordering::SeqCst);
            let at_cap = ctx.stats.active.load(Ordering::SeqCst) >= ctx.cap;
            let now = Instant::now();
            // deadline sweep: stalled / reclaimable / drain-closable
            // connections go back to a worker, which runs the state
            // machine that decides their fate (and sends the ERR) —
            // the poller schedules, it never judges
            let mut next_deadline: Option<Instant> = None;
            let mut i = 0;
            while i < parked.len() {
                let deadline = parked[i].next_deadline(&ctx.cfg, at_cap);
                let due = deadline.is_some_and(|d| d <= now);
                if due || (draining && parked[i].drain_closable()) {
                    (ctx.enqueue)(parked.swap_remove(i));
                    continue;
                }
                if let Some(d) = deadline {
                    next_deadline = Some(next_deadline.map_or(d, |n| n.min(d)));
                }
                i += 1;
            }
            let timeout = match next_deadline {
                Some(d) => d.saturating_duration_since(now).min(tick),
                None => tick,
            };
            #[cfg(unix)]
            self.wait_ready(&mut parked, &mut fds, timeout, &ctx);
            #[cfg(not(unix))]
            self.wait_ready(&mut parked, timeout, &ctx);
        }
    }

    /// Block until some parked fd matches its connection's interest, a
    /// wake arrives, or `timeout` passes; ready connections move to
    /// the run queue.
    #[cfg(unix)]
    fn wait_ready(
        &self,
        parked: &mut Vec<Connection>,
        fds: &mut Vec<sys::PollFd>,
        timeout: Duration,
        ctx: &PollerCtx,
    ) {
        fds.clear();
        fds.push(sys::PollFd::new(self.wake.read_fd(), sys::POLLIN));
        for conn in parked.iter() {
            let (read, write) = conn.poll_interest(&ctx.cfg);
            let mut events = 0i16;
            if read {
                events |= sys::POLLIN;
            }
            if write {
                events |= sys::POLLOUT;
            }
            fds.push(sys::PollFd::new(conn.fd(), events));
        }
        if sys::poll_fds(fds, timeout) == 0 {
            return;
        }
        if fds[0].ready() {
            self.wake.drain();
        }
        // reverse order keeps earlier indices valid across swap_remove
        for idx in (0..parked.len()).rev() {
            if fds[idx + 1].ready() {
                (ctx.enqueue)(parked.swap_remove(idx));
            }
        }
    }

    /// Portability fallback: no readiness primitive — sleep one tick,
    /// then hand everything back to the run queue (the pre-poller
    /// rotation behavior).
    #[cfg(not(unix))]
    fn wait_ready(&self, parked: &mut Vec<Connection>, timeout: Duration, ctx: &PollerCtx) {
        std::thread::sleep(timeout.min(Duration::from_millis(50)));
        for conn in parked.drain(..) {
            (ctx.enqueue)(conn);
        }
    }
}

#[cfg(test)]
mod tests {
    #[cfg(unix)]
    mod unix {
        use super::super::sys;
        use std::time::Duration;

        #[test]
        fn wake_pipe_makes_poll_return() {
            let wp = sys::WakePipe::new().unwrap();
            assert!(!sys::poll_one(
                wp.read_fd(),
                sys::POLLIN,
                Duration::from_millis(0)
            ));
            wp.wake();
            assert!(sys::poll_one(
                wp.read_fd(),
                sys::POLLIN,
                Duration::from_millis(1000)
            ));
            wp.drain();
            assert!(!sys::poll_one(
                wp.read_fd(),
                sys::POLLIN,
                Duration::from_millis(0)
            ));
        }

        #[test]
        fn poll_sees_tcp_readability_and_writability() {
            use std::io::Write;
            use std::os::unix::io::AsRawFd;
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let mut tx = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            let (rx, _) = listener.accept().unwrap();
            assert!(!sys::poll_one(
                rx.as_raw_fd(),
                sys::POLLIN,
                Duration::from_millis(0)
            ));
            // a fresh socket's send buffer is empty: writable at once
            assert!(sys::poll_one(
                rx.as_raw_fd(),
                sys::POLLOUT,
                Duration::from_millis(100)
            ));
            tx.write_all(b"x").unwrap();
            assert!(sys::poll_one(
                rx.as_raw_fd(),
                sys::POLLIN,
                Duration::from_secs(2)
            ));
        }

        #[test]
        fn nofile_limit_is_reported() {
            // asking for nothing still reports the current soft limit
            assert!(sys::raise_nofile_limit(0) > 0);
        }
    }
}
