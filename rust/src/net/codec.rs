//! The wire codec — the single source of truth for everything that
//! frames bytes on a pico connection.
//!
//! Every protocol magic lives here and **only** here (CI greps for
//! stray re-definitions):
//!
//! * [`FRAME_PROTO`] — the binary framing protocol identifier, echoed
//!   by the `BINARY` upgrade handshake (`OK binary proto=...`). A frame
//!   is a little-endian `u32` byte length followed by that many payload
//!   bytes, capped at [`MAX_FRAME_BYTES`].
//! * [`SNAPSHOT_MAGIC`] — index snapshots ([`crate::shard::snapshot`]).
//! * [`MANIFEST_MAGIC`] — shard manifests ([`crate::cluster::wire`]).
//! * [`DELTA_MAGIC`] — epoch delta chains ([`crate::cluster::wire`]).
//! * [`HANDOFF_MAGIC`] — owned-vertex handoff payloads shipped by the
//!   rebalancer when a shard splits or merges ([`crate::cluster::wire`]).
//!
//! The read/write path here is shared by the server ([`crate::net::pool`]
//! / [`crate::net::conn`]), the remote-shard client
//! ([`crate::cluster::remote`] via [`crate::net::client`]), snapshot
//! shipping, and the CLI — none of them hand-roll framing any more.
//! [`Cursor`] is the shared bounds-checked reader every payload decoder
//! (snapshots, manifests, delta chains) parses untrusted bytes with:
//! counts are checked against the remaining byte budget *before* any
//! allocation, and [`Cursor::done`] rejects trailing garbage.

use std::io::{Read, Write};

/// Binary framing protocol identifier (`BINARY` upgrade handshake).
pub const FRAME_PROTO: &str = "PICOBIN1";

/// Index-snapshot payload magic (see [`crate::shard::snapshot`]).
pub const SNAPSHOT_MAGIC: &[u8; 8] = b"PICOSNP1";

/// Shard-manifest payload magic (see [`crate::cluster::wire`]).
pub const MANIFEST_MAGIC: &[u8; 8] = b"PICOSHD1";

/// Epoch-delta-chain payload magic (see [`crate::cluster::wire`]).
pub const DELTA_MAGIC: &[u8; 8] = b"PICODLT1";

/// Owned-vertex handoff payload magic (see [`crate::cluster::wire`]).
/// Carries a set of owned vertices — adjacency and committed coreness —
/// from one shard to another during a rebalance split or merge.
pub const HANDOFF_MAGIC: &[u8; 8] = b"PICOHND1";

/// Longest protocol line accepted from the wire. A client streaming
/// bytes with no newline must not grow the server's line buffer without
/// bound (memory-exhaustion class).
pub const MAX_LINE_BYTES: usize = 4096;

/// Largest binary frame accepted or sent. Bounds the allocation a single
/// length-prefix can demand; sized for snapshots of the largest suite
/// graphs with ample headroom.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Size of the length prefix in front of every binary frame — shared
/// with the resumable frame reader in [`crate::net::conn`], which
/// reassembles the header across non-blocking reads.
pub const FRAME_HEADER_BYTES: usize = 4;

/// Write one length-prefixed frame — the binary protocol's only framing
/// primitive, shared by the server, every client, and the tests.
/// Bodies above `u32::MAX` cannot be length-prefixed and error out
/// instead of silently truncating the prefix.
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> std::io::Result<()> {
    let Ok(len) = u32::try_from(body.len()) else {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "frame body exceeds u32::MAX bytes",
        ));
    };
    w.write_all(&len.to_le_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Read one length-prefixed frame: `Ok(None)` at a clean EOF,
/// `ErrorKind::InvalidData` when the declared length exceeds `max`
/// (nothing past the header is consumed in that case).
pub fn read_frame(reader: &mut impl Read, max: usize) -> std::io::Result<Option<Vec<u8>>> {
    let mut header = [0u8; FRAME_HEADER_BYTES];
    match reader.read_exact(&mut header) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let len = u32::from_le_bytes(header) as usize;
    if len > max {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "frame too large",
        ));
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok(Some(body))
}

/// Split a frame body into its head line and the raw payload after the
/// first `\n` (empty when there is none) — the request *and* reply
/// convention of the binary protocol.
pub fn split_frame(body: &[u8]) -> (&[u8], &[u8]) {
    match body.iter().position(|&b| b == b'\n') {
        Some(i) => (&body[..i], &body[i + 1..]),
        None => (body, &[][..]),
    }
}

// --- the trace-id head-line field ----------------------------------------
//
// Cross-host flush tracing ([`crate::obs::trace`]) rides the existing
// verbs instead of growing the frame format: the coordinator appends a
// trailing ` trace=<hex>` token to a shard-verb head line, and the host
// answers with ` trace=<hex> us=<micros>` appended to its reply head.
// Both sides degrade cleanly — a host that predates the field ignores
// the trailing token (arg parsers are positional), and a coordinator
// simply finds no `us=` in the reply.

/// Append the trace-id field to a request head line.
pub fn attach_trace(line: &str, id: u64) -> String {
    format!("{line} trace={id:x}")
}

/// Split a trailing `trace=<hex>` token off a request head line; lines
/// without one come back unchanged.
pub fn extract_trace(head: &str) -> (&str, Option<u64>) {
    if let Some(idx) = head.rfind(" trace=") {
        let tok = &head[idx + " trace=".len()..];
        if !tok.is_empty() && !tok.contains(' ') {
            if let Ok(id) = u64::from_str_radix(tok, 16) {
                return (&head[..idx], Some(id));
            }
        }
    }
    (head, None)
}

/// Tag a reply frame's head line with `trace=<hex> us=<micros>` —
/// inserted before the first `\n` so any payload stays untouched.
pub fn tag_reply_trace(reply: &mut Vec<u8>, id: u64, us: u64) {
    let tag = format!(" trace={id:x} us={us}");
    match reply.iter().position(|&b| b == b'\n') {
        Some(i) => {
            let mut out = Vec::with_capacity(reply.len() + tag.len());
            out.extend_from_slice(&reply[..i]);
            out.extend_from_slice(tag.as_bytes());
            out.extend_from_slice(&reply[i..]);
            *reply = out;
        }
        None => reply.extend_from_slice(tag.as_bytes()),
    }
}

/// The `us=<micros>` field of a tagged reply head — the remote
/// handler's own measured time. `None` from pre-trace servers.
pub fn reply_us(head: &str) -> Option<u64> {
    head.split_whitespace()
        .find_map(|t| t.strip_prefix("us="))
        .and_then(|v| v.parse().ok())
}

/// A bounds-checked reader over untrusted payload bytes — the one
/// decoder primitive snapshots, manifests, and delta chains all parse
/// with. Never panics on truncated input; every `take` is checked.
pub struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Self { bytes, pos: 0 }
    }

    /// The next `n` bytes, or an error naming the offset when the
    /// payload is truncated.
    pub fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let Some(end) = self.pos.checked_add(n).filter(|&e| e <= self.bytes.len()) else {
            anyhow::bail!(
                "truncated payload: needed {n} bytes at offset {}, have {}",
                self.pos,
                self.bytes.len() - self.pos
            );
        };
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A `u64` count that must fit `per`-byte elements in what remains —
    /// the pre-allocation budget check every length-prefixed list goes
    /// through.
    pub fn count(&mut self, per: usize, what: &str) -> anyhow::Result<usize> {
        let n = self.u64()? as usize;
        match n.checked_mul(per) {
            Some(bytes) if bytes <= self.bytes.len() - self.pos => Ok(n),
            _ => anyhow::bail!("{what} count {n} exceeds the payload"),
        }
    }

    pub fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }

    /// Reject trailing garbage once a decoder believes it is finished.
    pub fn done(&self, what: &str) -> anyhow::Result<()> {
        if self.remaining() != 0 {
            anyhow::bail!("{what}: {} trailing bytes", self.remaining());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_round_trip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut r, 1024).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r, 1024).unwrap().unwrap(), b"");
        assert!(read_frame(&mut r, 1024).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn oversized_declared_length_is_invalid_data() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &vec![0u8; 64]).unwrap();
        let mut r = std::io::Cursor::new(buf);
        let err = read_frame(&mut r, 8).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn truncated_body_is_unexpected_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"0123456789").unwrap();
        buf.truncate(buf.len() - 3);
        let mut r = std::io::Cursor::new(buf);
        let err = read_frame(&mut r, 1024).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn split_frame_handles_missing_payload() {
        assert_eq!(split_frame(b"OK x=1\nabc"), (&b"OK x=1"[..], &b"abc"[..]));
        assert_eq!(split_frame(b"OK bare"), (&b"OK bare"[..], &b""[..]));
        assert_eq!(split_frame(b"head\n"), (&b"head"[..], &b""[..]));
    }

    #[test]
    fn cursor_checks_every_read() {
        let bytes = [1u8, 2, 3, 4, 5, 6, 7, 8, 9];
        let mut c = Cursor::new(&bytes);
        assert_eq!(c.u8().unwrap(), 1);
        assert_eq!(c.u32().unwrap(), u32::from_le_bytes([2, 3, 4, 5]));
        assert_eq!(c.remaining(), 4);
        assert!(c.u64().is_err(), "truncated");
        assert!(c.done("x").is_err(), "trailing bytes flagged");
        // counts beyond the budget fail before any allocation
        let huge = u64::MAX.to_le_bytes();
        let mut c = Cursor::new(&huge);
        assert!(c.count(4, "list").is_err());
        // a zero count on an exactly-empty tail passes
        let empty = 0u64.to_le_bytes();
        let mut c = Cursor::new(&empty);
        assert_eq!(c.count(8, "list").unwrap(), 0);
        c.done("list").unwrap();
    }

    #[test]
    fn trace_tokens_round_trip_on_heads_and_replies() {
        let line = attach_trace("APPLY 3 1 0 2", 0xbeef);
        assert_eq!(line, "APPLY 3 1 0 2 trace=beef");
        assert_eq!(extract_trace(&line), ("APPLY 3 1 0 2", Some(0xbeef)));
        // untraced and malformed heads pass through unchanged
        assert_eq!(extract_trace("APPLY 3 1 0 2"), ("APPLY 3 1 0 2", None));
        assert_eq!(extract_trace("GET trace=zz"), ("GET trace=zz", None));
        assert_eq!(extract_trace("GET trace=7 x"), ("GET trace=7 x", None));

        let mut reply = b"OK applied=3\npayload".to_vec();
        tag_reply_trace(&mut reply, 0xbeef, 120);
        assert_eq!(reply, b"OK applied=3 trace=beef us=120\npayload");
        let (head, _) = split_frame(&reply);
        assert_eq!(reply_us(std::str::from_utf8(head).unwrap()), Some(120));

        let mut bare = b"OK done".to_vec();
        tag_reply_trace(&mut bare, 1, 7);
        assert_eq!(bare, b"OK done trace=1 us=7");
        assert_eq!(reply_us("OK done"), None);
    }

    #[test]
    fn magics_are_distinct() {
        let all = [SNAPSHOT_MAGIC, MANIFEST_MAGIC, DELTA_MAGIC, HANDOFF_MAGIC];
        for (i, a) in all.iter().enumerate() {
            assert_eq!(a.len(), 8);
            for b in &all[i + 1..] {
                assert_ne!(a, b);
            }
        }
        assert_eq!(FRAME_PROTO.len(), 8);
    }
}
