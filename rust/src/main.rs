//! `pico` — the launcher binary.
//!
//! See [`pico::cli::USAGE`] or run `pico help`.

use anyhow::Result;
use pico::cli::{args::Args, commands, USAGE};
use pico::config::Config;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse_with_sub(
        &raw,
        &["metrics", "no-validate", "help", "json", "binary", "events", "health", "apply"],
        &["cluster"],
    )?;

    let cfg = Config::load(args.get("config").map(std::path::Path::new))?;

    match args.command.as_str() {
        "run" => commands::cmd_run(&args, &cfg),
        // `bench` is an alias: the suite runner is the in-CLI benchmark
        "suite" | "bench" => commands::cmd_suite(&args, &cfg),
        "serve" => commands::cmd_serve(&args, &cfg),
        "cluster" => commands::cmd_cluster(&args, &cfg),
        "top" => commands::cmd_top(&args, &cfg),
        "query" => commands::cmd_query(&args, &cfg),
        "stats" => commands::cmd_stats(&args, &cfg),
        "analyze" => commands::cmd_analyze(&args, &cfg),
        "doctor" => commands::cmd_doctor(&args, &cfg),
        "list" => commands::cmd_list(&args, &cfg),
        "" | "help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprint!("{USAGE}");
            anyhow::bail!("unknown command '{other}'")
        }
    }
}
