"""Layer 2: the vectorised k-core step functions (VETGA [20] lineage).

Both paradigms are expressed as dense, statically-shaped step functions
over a padded neighbor matrix, calling the Layer-1 Pallas kernels:

* :func:`peel_step` — one sub-iteration of the vectorised PeelOne: find
  the frontier ``alive & core == k``, gather its incidence counts, apply
  the assertion clamp (Pallas kernel), retire the frontier.
* :func:`hindex_step` — one Index2core sweep: gather neighbor estimates,
  recompute every h-index (Pallas threshold-matrix kernel).

The Rust runtime drives these to convergence; Python never runs at
request time. Shapes are fixed per (N, D) bucket and AOT-lowered by
:mod:`compile.aot`.
"""

import jax.numpy as jnp

from .kernels.hindex import hindex_rows
from .kernels.peel import assert_clamp


def peel_step(core, alive, nbrs, k):
    """One vectorised PeelOne sub-iteration at level ``k``.

    Args:
      core:  i32[N] — merged residual-degree/coreness array (Alg 4).
      alive: i32[N] — 1 for residual vertices.
      nbrs:  i32[N, D] — padded neighbor matrix (pad index = N).
      k:     i32[] — current level.

    Returns (new_core, new_alive, frontier_count, alive_count); removed
    vertices keep ``core == k`` (their coreness, Theorem 1).
    """
    n = core.shape[0]
    frontier = (alive == 1) & (core == k)
    f_ext = jnp.concatenate(
        [frontier.astype(jnp.int32), jnp.zeros((1,), jnp.int32)]
    )
    dec = jnp.sum(f_ext[nbrs], axis=1).astype(jnp.int32)  # [N]
    new_alive = jnp.where(frontier, 0, alive).astype(jnp.int32)
    clamped = assert_clamp(core, dec, k, block=min(256, n))
    new_core = jnp.where(new_alive == 1, clamped, core).astype(jnp.int32)
    return (
        new_core,
        new_alive,
        jnp.sum(frontier.astype(jnp.int32)),
        jnp.sum(new_alive),
    )


def hindex_step(core, nbrs):
    """One vectorised Index2core sweep.

    Args:
      core: i32[N] — current estimates (init: degrees).
      nbrs: i32[N, D] — padded neighbor matrix (pad index = N).

    Returns (new_core, changed_count).
    """
    n = core.shape[0]
    core_ext = jnp.concatenate([core, jnp.zeros((1,), jnp.int32)])
    vals = core_ext[nbrs]  # [N, D] — pads gather the 0 sentinel
    h = hindex_rows(vals, core, block=min(128, n))
    changed = jnp.sum((h != core).astype(jnp.int32))
    return h, changed


# The (N, D) buckets compiled by `make artifacts`. Kept here so aot.py,
# the python tests, and (via manifest.txt) the rust runtime agree.
BUCKETS = [
    (8, 4),
    (64, 8),
    (256, 16),
    (1024, 32),
    (4096, 64),
]
