"""Layer-1 Pallas kernel: the batched assertion update (atomicSub_{>=k}).

The paper's novel atomic (§III.B) computes, per vertex,
``old > k ? old - dec : k`` clamped at the floor ``k``. On a GPU this is a
CAS transaction per edge; vectorised for the TPU it becomes one fused
select/max over a tile of vertices:

    new_core[b] = core[b] > k ? max(core[b] - dec[b], k) : core[b]

`dec[b]` (how many frontier neighbors vertex b lost this step) is computed
at Layer 2 by a dense gather-reduce; the kernel is the clamp itself, tiled
B vertices per grid step. The scalar `k` rides along as a (1,)-shaped
block broadcast to every tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assert_clamp_kernel(core_ref, dec_ref, k_ref, out_ref):
    core = core_ref[...]
    dec = dec_ref[...]
    k = k_ref[0]
    out_ref[...] = jnp.where(
        core > k, jnp.maximum(core - dec, k), core
    ).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block",))
def assert_clamp(core, dec, k, block=256):
    """Batched atomicSub_{>=k}: core[N], dec[N] i32, k i32[1] -> [N] i32."""
    n = core.shape[0]
    block = min(block, n)
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    grid = (n // block,)
    return pl.pallas_call(
        _assert_clamp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),  # broadcast scalar k
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(core.astype(jnp.int32), dec.astype(jnp.int32), jnp.asarray(k, jnp.int32).reshape(1))
