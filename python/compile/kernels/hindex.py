"""Layer-1 Pallas kernel: tiled h-index via the threshold-compare matrix.

GPU -> TPU adaptation (DESIGN.md §Hardware-Adaptation): the CUDA HINDEX
scatters into a per-vertex ``histo[]`` with atomics — random access that a
TPU has no fast path for. We reformulate Step I as a *dense* compare:

    cnt[b, h] = sum_j (vals[b, j] >= h)        h = 1..D

which is a [B, D] x [D] broadcast-compare-reduce on the VPU lanes (and is
MXU-expressible as a one-hot matmul), followed by Step II as a masked
row-max. The BlockSpec tiles B vertices per grid step, bounding VMEM at
B*D*4 bytes for the value tile plus the [B, D] compare accumulator.

interpret=True everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; real-TPU numbers are estimated in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hindex_tile_kernel(vals_ref, cap_ref, out_ref):
    """One tile: vals[B, D] i32, cap[B] i32 -> h[B] i32."""
    vals = vals_ref[...]
    cap = cap_ref[...]
    b, d = vals.shape
    # Thresholds h = 1..D as an in-kernel iota: materialising them with
    # jnp.arange would make the kernel close over a traced constant,
    # which pallas_call rejects ("captures constants ... pass them as
    # inputs") — and Mosaic wants rank >= 2 iota on real TPUs anyway.
    thr = jax.lax.broadcasted_iota(jnp.int32, (b, d), 1) + 1  # [B, D]
    # Step I (dense histogram analog): cnt[b, h] = #{j : vals[b, j] >= h}.
    cnt = jnp.sum(
        (vals[:, :, None] >= thr[:, None, :]).astype(jnp.int32), axis=1
    )  # [B, D]
    # Step II: h = max{h : cnt >= h, h <= cap}.
    ok = (cnt >= thr) & (thr <= cap[:, None])
    out_ref[...] = jnp.max(jnp.where(ok, thr, 0), axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block",))
def hindex_rows(vals, cap, block=128):
    """h-index of every row: vals[N, D] i32, cap[N] i32 -> [N] i32.

    N must be a multiple of `block` (callers pad to the bucket size).
    """
    n, d = vals.shape
    block = min(block, n)
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    grid = (n // block,)
    return pl.pallas_call(
        _hindex_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(vals.astype(jnp.int32), cap.astype(jnp.int32))


def vmem_bytes_estimate(block, d):
    """VMEM working-set estimate per tile for DESIGN.md §Perf: the value
    tile, the [B, D] compare/count accumulator, thresholds and outputs."""
    return block * d * 4 + block * d * 4 + d * 4 + 2 * block * 4
