"""Pure-jnp / pure-python oracles for the Pallas kernels.

Everything in this file is the *correctness reference*: no Pallas, no
tiling — just the mathematical definition. pytest compares every kernel
against these, and the vectorised-engine tests compare the full step loop
against a serial python peel.
"""

import jax.numpy as jnp
import numpy as np


def hindex_row_py(vals, cap):
    """h-index of one row, python ints: max h <= cap with #{v >= h} >= h."""
    best = 0
    for h in range(1, int(cap) + 1):
        if sum(1 for v in vals if v >= h) >= h:
            best = h
    return best


def hindex_rows_ref(vals, cap):
    """Vectorised reference: vals[B, D] i32, cap[B] i32 -> h[B] i32.

    cnt[b, h] = #{j : vals[b, j] >= h} for h = 1..D, then
    h[b] = max{h : cnt[b, h] >= h and h <= cap[b]} (0 if none).
    """
    vals = jnp.asarray(vals, jnp.int32)
    cap = jnp.asarray(cap, jnp.int32)
    d = vals.shape[1]
    thresholds = jnp.arange(1, d + 1, dtype=jnp.int32)  # [D]
    cnt = jnp.sum(vals[:, :, None] >= thresholds[None, None, :], axis=1)  # [B, D]
    ok = (cnt >= thresholds[None, :]) & (thresholds[None, :] <= cap[:, None])
    return jnp.max(jnp.where(ok, thresholds[None, :], 0), axis=1).astype(jnp.int32)


def assert_clamp_ref(core, dec, k):
    """The vectorised atomicSub_{>=k}: core[b] > k -> max(core - dec, k)."""
    core = jnp.asarray(core, jnp.int32)
    dec = jnp.asarray(dec, jnp.int32)
    return jnp.where(core > k, jnp.maximum(core - dec, k), core).astype(jnp.int32)


def peel_step_ref(core, alive, nbrs, k):
    """One vectorised PeelOne step (reference semantics).

    core, alive: i32[N]; nbrs: i32[N, D] padded with N; k: scalar.
    Returns (new_core, new_alive, frontier_count, alive_count).
    """
    core = jnp.asarray(core, jnp.int32)
    alive = jnp.asarray(alive, jnp.int32)
    nbrs = jnp.asarray(nbrs, jnp.int32)
    frontier = (alive == 1) & (core == k)
    f_ext = jnp.concatenate([frontier.astype(jnp.int32), jnp.zeros((1,), jnp.int32)])
    dec = jnp.sum(f_ext[nbrs], axis=1)
    new_alive = jnp.where(frontier, 0, alive)
    new_core = jnp.where(new_alive == 1, assert_clamp_ref(core, dec, k), core)
    return (
        new_core.astype(jnp.int32),
        new_alive.astype(jnp.int32),
        jnp.sum(frontier.astype(jnp.int32)),
        jnp.sum(new_alive),
    )


def hindex_step_ref(core, nbrs):
    """One vectorised Index2core sweep (reference semantics).

    core: i32[N]; nbrs: i32[N, D] padded with N.
    Returns (new_core, changed_count).
    """
    core = jnp.asarray(core, jnp.int32)
    nbrs = jnp.asarray(nbrs, jnp.int32)
    core_ext = jnp.concatenate([core, jnp.zeros((1,), jnp.int32)])
    vals = core_ext[nbrs]  # [N, D]; pads read the 0 sentinel
    h = hindex_rows_ref(vals, core)
    changed = jnp.sum((h != core).astype(jnp.int32))
    return h.astype(jnp.int32), changed


def serial_coreness_py(n, edges):
    """Plain-python peel for ground truth in the python tests."""
    adj = [[] for _ in range(n)]
    for u, v in edges:
        adj[u].append(v)
        adj[v].append(u)
    deg = [len(a) for a in adj]
    removed = [False] * n
    core = [0] * n
    k = 0
    left = n
    while left > 0:
        frontier = [v for v in range(n) if not removed[v] and deg[v] <= k]
        if not frontier:
            k += 1
            continue
        while frontier:
            v = frontier.pop()
            if removed[v]:
                continue
            removed[v] = True
            core[v] = k
            left -= 1
            for u in adj[v]:
                if not removed[u]:
                    deg[u] -= 1
                    if deg[u] <= k:
                        frontier.append(u)
    return core


def pad_neighbors(n, edges, d):
    """CSR -> dense padded neighbor matrix (pad index = n)."""
    adj = [[] for _ in range(n)]
    for u, v in edges:
        adj[u].append(v)
        adj[v].append(u)
    out = np.full((n, d), n, dtype=np.int32)
    for v, a in enumerate(adj):
        if len(a) > d:
            raise ValueError(f"degree {len(a)} exceeds bucket width {d}")
        out[v, : len(a)] = sorted(a)
    return out
