"""AOT lowering: jax step functions -> HLO *text* artifacts.

HLO text (NOT serialized HloModuleProto / jax.export bytes) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the xla crate's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts

Emits, per (N, D) bucket:
    peel_n{N}_d{D}.hlo.txt      — peel_step(core, alive, nbrs, k)
    hindex_n{N}_d{D}.hlo.txt    — hindex_step(core, nbrs)
plus `manifest.txt` (one `N D` pair per line) consumed by the rust
runtime's bucket selection.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import BUCKETS, hindex_step, peel_step


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True so the
    rust side unwraps one tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(n: int, d: int):
    """Lower both step functions for one (N, D) bucket."""
    core = jax.ShapeDtypeStruct((n,), jnp.int32)
    alive = jax.ShapeDtypeStruct((n,), jnp.int32)
    nbrs = jax.ShapeDtypeStruct((n, d), jnp.int32)
    k = jax.ShapeDtypeStruct((), jnp.int32)
    peel = jax.jit(peel_step).lower(core, alive, nbrs, k)
    hidx = jax.jit(hindex_step).lower(core, nbrs)
    return to_hlo_text(peel), to_hlo_text(hidx)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    parser.add_argument(
        "--buckets",
        default=None,
        help="comma-separated N:D pairs overriding the default bucket set",
    )
    args = parser.parse_args()

    buckets = BUCKETS
    if args.buckets:
        buckets = [
            tuple(int(x) for x in pair.split(":")) for pair in args.buckets.split(",")
        ]

    os.makedirs(args.out, exist_ok=True)
    manifest_lines = []
    for n, d in buckets:
        peel_text, hidx_text = lower_bucket(n, d)
        peel_path = os.path.join(args.out, f"peel_n{n}_d{d}.hlo.txt")
        hidx_path = os.path.join(args.out, f"hindex_n{n}_d{d}.hlo.txt")
        with open(peel_path, "w") as f:
            f.write(peel_text)
        with open(hidx_path, "w") as f:
            f.write(hidx_text)
        manifest_lines.append(f"{n} {d}")
        print(
            f"bucket ({n:5d},{d:3d}): wrote {len(peel_text):9d} + "
            f"{len(hidx_text):9d} chars"
        )
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(buckets)} buckets -> {args.out}/manifest.txt")


if __name__ == "__main__":
    main()
