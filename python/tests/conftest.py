"""Test-suite gating for the Layer 1/2 kernel tests.

Two jobs:

* Put ``python/`` on ``sys.path`` so ``from compile.kernels...`` imports
  resolve no matter where pytest is invoked from (repo root, ``python/``,
  or CI).
* Skip-clean when a test-only dependency is absent — the Python kernel
  tests mirror the ``xla`` cargo feature: without JAX (or the hypothesis
  property-testing dep) the suite must report "skipped", never "broken".
  The kernel test modules import their deps at module scope, so modules
  with a missing dep are excluded from collection entirely;
  ``test_environment.py`` needs nothing and stays collected, so the
  suite is never empty (pytest exits non-zero on zero collected tests).
"""

import importlib.util
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


def _have(mod):
    return importlib.util.find_spec(mod) is not None


HAVE_JAX = _have("jax")

#: module -> deps it imports at module scope
REQUIRES = {
    "test_aot.py": ["jax"],
    "test_hindex_kernel.py": ["jax", "hypothesis"],
    "test_model.py": ["jax", "hypothesis"],
    "test_peel_kernel.py": ["jax", "hypothesis"],
}

collect_ignore = [
    mod for mod, deps in REQUIRES.items() if not all(_have(d) for d in deps)
]
