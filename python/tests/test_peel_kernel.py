"""L1 correctness: the Pallas assertion-clamp kernel (batched
atomicSub_{>=k}) vs the jnp reference."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.peel import assert_clamp
from compile.kernels.ref import assert_clamp_ref


@st.composite
def clamp_case(draw):
    n = draw(st.integers(min_value=1, max_value=32))
    core = draw(st.lists(st.integers(min_value=0, max_value=30), min_size=n, max_size=n))
    dec = draw(st.lists(st.integers(min_value=0, max_value=10), min_size=n, max_size=n))
    k = draw(st.integers(min_value=0, max_value=12))
    return np.array(core, np.int32), np.array(dec, np.int32), k


@settings(max_examples=80, deadline=None)
@given(clamp_case())
def test_matches_reference(case):
    core, dec, k = case
    got = assert_clamp(jnp.asarray(core), jnp.asarray(dec), k, block=core.shape[0])
    want = assert_clamp_ref(core, dec, k)
    np.testing.assert_array_equal(np.array(got), np.array(want))


def test_semantics_of_the_floor():
    core = np.array([10, 5, 4, 5, 0], np.int32)
    dec = np.array([2, 3, 1, 0, 9], np.int32)
    k = 5
    got = np.array(assert_clamp(jnp.asarray(core), jnp.asarray(dec), k, block=5))
    # 10-2=8; 5 not > k (untouched); 4 below k from an earlier level
    # (untouched); 5 untouched; 0 untouched.
    np.testing.assert_array_equal(got, [8, 5, 4, 5, 0])


def test_never_below_floor_when_above():
    core = np.array([9, 9, 9, 9], np.int32)
    dec = np.array([100, 1, 0, 9], np.int32)
    got = np.array(assert_clamp(jnp.asarray(core), jnp.asarray(dec), 3, block=4))
    assert (got >= 3).all()
    np.testing.assert_array_equal(got, [3, 8, 9, 3])


def test_tiling_invariance():
    rng = np.random.default_rng(11)
    core = rng.integers(0, 30, size=16).astype(np.int32)
    dec = rng.integers(0, 8, size=16).astype(np.int32)
    a = np.array(assert_clamp(jnp.asarray(core), jnp.asarray(dec), 4, block=16))
    b = np.array(assert_clamp(jnp.asarray(core), jnp.asarray(dec), 4, block=4))
    np.testing.assert_array_equal(a, b)
