"""L2 correctness: driving the vectorised step functions to convergence
reproduces the serial peel's coreness on random graphs — both paradigms."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.ref import pad_neighbors, serial_coreness_py
from compile.model import BUCKETS, hindex_step, peel_step


def random_graph(rng, n, m, d_cap):
    """Random simple graph with max degree <= d_cap."""
    deg = [0] * n
    edges = set()
    for _ in range(m * 3):
        if len(edges) >= m:
            break
        u, v = int(rng.integers(n)), int(rng.integers(n))
        if u == v:
            continue
        e = (min(u, v), max(u, v))
        if e in edges or deg[u] >= d_cap or deg[v] >= d_cap:
            continue
        edges.add(e)
        deg[u] += 1
        deg[v] += 1
    return sorted(edges)


def run_peel(n, d, edges):
    nbrs = jnp.asarray(pad_neighbors(n, edges, d))
    deg = np.zeros(n, np.int32)
    for u, v in edges:
        deg[u] += 1
        deg[v] += 1
    core = jnp.asarray(deg)
    alive = jnp.asarray((deg > 0).astype(np.int32))
    k, total_alive, steps = 1, int(jnp.sum(alive)), 0
    while total_alive > 0:
        core, alive, fc, ac = peel_step(core, alive, nbrs, jnp.asarray(k, jnp.int32))
        if int(fc) == 0:
            k += 1
        total_alive = int(ac)
        steps += 1
        assert steps < 10 * n + 100, "vectorised peel failed to converge"
    return list(np.array(core))


def run_hindex(n, d, edges):
    nbrs = jnp.asarray(pad_neighbors(n, edges, d))
    deg = np.zeros(n, np.int32)
    for u, v in edges:
        deg[u] += 1
        deg[v] += 1
    core = jnp.asarray(deg)
    for _ in range(n + 2):
        core, ch = hindex_step(core, nbrs)
        if int(ch) == 0:
            return list(np.array(core))
    raise AssertionError("h-index iteration failed to converge")


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_peel_loop_matches_serial(seed):
    rng = np.random.default_rng(seed)
    n, d = 16, 8
    edges = random_graph(rng, n, 24, d)
    want = serial_coreness_py(n, edges)
    got = run_peel(n, d, edges)
    assert got == want, (edges, got, want)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_hindex_loop_matches_serial(seed):
    rng = np.random.default_rng(seed)
    n, d = 16, 8
    edges = random_graph(rng, n, 24, d)
    want = serial_coreness_py(n, edges)
    got = run_hindex(n, d, edges)
    assert got == want, (edges, got, want)


def test_g1_both_paradigms():
    edges = [(0, 5), (1, 5), (2, 3), (2, 4), (3, 4), (3, 5), (4, 5)]
    want = [1, 1, 2, 2, 2, 2, 0, 0]
    assert run_peel(8, 4, edges) == want
    assert run_hindex(8, 4, edges) == want


def test_step_shapes_and_dtypes():
    n, d = 8, 4
    core = jnp.zeros((n,), jnp.int32)
    alive = jnp.zeros((n,), jnp.int32)
    nbrs = jnp.full((n, d), n, jnp.int32)
    c, a, fc, ac = peel_step(core, alive, nbrs, jnp.asarray(1, jnp.int32))
    assert c.shape == (n,) and a.shape == (n,) and fc.shape == () and ac.shape == ()
    assert c.dtype == a.dtype == fc.dtype == jnp.int32
    h, ch = hindex_step(core, nbrs)
    assert h.shape == (n,) and ch.shape == ()


def test_degree_overflow_rejected():
    with pytest.raises(ValueError, match="exceeds bucket width"):
        pad_neighbors(4, [(0, 1), (0, 2), (0, 3)], 2)


def test_buckets_are_sane():
    for n, d in BUCKETS:
        assert n % min(128, n) == 0
        assert n % min(256, n) == 0
        assert d <= n
