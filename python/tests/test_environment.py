"""Environment sanity — the one module that never needs JAX.

Keeps the suite non-empty when the kernel tests are skip-cleaned (see
``conftest.py``), and pins the repo layout the ``compile`` imports rely
on, so a silent "0 tests ran" can never masquerade as a green run.
"""

import importlib.util
import pathlib

import conftest


def test_kernel_sources_are_where_the_imports_expect():
    root = pathlib.Path(__file__).resolve().parents[1]
    for rel in [
        "compile/aot.py",
        "compile/model.py",
        "compile/kernels/__init__.py",
        "compile/kernels/peel.py",
        "compile/kernels/hindex.py",
        "compile/kernels/ref.py",
    ]:
        assert (root / rel).is_file(), f"missing {rel}"


def test_dependency_gating_is_consistent():
    # the conftest's skip decision must match what an import would find;
    # a broken half-installed jax should surface here, not as a cryptic
    # collection error
    assert conftest.HAVE_JAX == (importlib.util.find_spec("jax") is not None)
    if not conftest.HAVE_JAX:
        assert sorted(conftest.collect_ignore) == sorted(conftest.REQUIRES)
    for mod in conftest.collect_ignore:
        assert mod in conftest.REQUIRES
