"""L1 correctness: the Pallas threshold-matrix h-index kernel vs the
pure-jnp reference and a plain-python definition — hypothesis sweeps over
shapes, values, and tilings."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.hindex import hindex_rows, vmem_bytes_estimate
from compile.kernels.ref import hindex_row_py, hindex_rows_ref


@st.composite
def rows_case(draw):
    b = draw(st.integers(min_value=1, max_value=16))
    d = draw(st.integers(min_value=1, max_value=12))
    vals = draw(
        st.lists(
            st.lists(st.integers(min_value=0, max_value=20), min_size=d, max_size=d),
            min_size=b,
            max_size=b,
        )
    )
    cap = draw(st.lists(st.integers(min_value=0, max_value=20), min_size=b, max_size=b))
    return np.array(vals, np.int32), np.array(cap, np.int32)


@settings(max_examples=60, deadline=None)
@given(rows_case())
def test_kernel_matches_python_definition(case):
    vals, cap = case
    got = np.array(hindex_rows(jnp.asarray(vals), jnp.asarray(cap), block=vals.shape[0]))
    for b in range(vals.shape[0]):
        assert got[b] == hindex_row_py(vals[b], cap[b]), (vals[b], cap[b])


@settings(max_examples=40, deadline=None)
@given(rows_case())
def test_kernel_matches_jnp_reference(case):
    vals, cap = case
    got = hindex_rows(jnp.asarray(vals), jnp.asarray(cap), block=vals.shape[0])
    want = hindex_rows_ref(vals, cap)
    np.testing.assert_array_equal(np.array(got), np.array(want))


@pytest.mark.parametrize("block", [1, 2, 4, 8])
def test_tiling_invariance(block):
    rng = np.random.default_rng(7)
    vals = rng.integers(0, 15, size=(8, 6)).astype(np.int32)
    cap = rng.integers(0, 15, size=(8,)).astype(np.int32)
    full = np.array(hindex_rows(jnp.asarray(vals), jnp.asarray(cap), block=8))
    tiled = np.array(hindex_rows(jnp.asarray(vals), jnp.asarray(cap), block=block))
    np.testing.assert_array_equal(full, tiled)


def test_paper_example_v5():
    # Fig. 6: neighbor estimates {1, 1, 2, 2, 3} -> h-index 2.
    vals = np.array([[1, 1, 2, 2, 3]], np.int32)
    cap = np.array([5], np.int32)
    assert int(hindex_rows(jnp.asarray(vals), jnp.asarray(cap), block=1)[0]) == 2


def test_zero_cap_and_padding():
    vals = np.array([[5, 5, 5, 0], [0, 0, 0, 0]], np.int32)
    cap = np.array([0, 4], np.int32)
    got = np.array(hindex_rows(jnp.asarray(vals), jnp.asarray(cap), block=2))
    assert got[0] == 0  # cap clamps to 0
    assert got[1] == 0  # all-zero padding row


def test_dtype_is_i32():
    vals = jnp.zeros((4, 4), jnp.int32)
    cap = jnp.zeros((4,), jnp.int32)
    assert hindex_rows(vals, cap, block=4).dtype == jnp.int32


def test_vmem_estimate_monotone():
    assert vmem_bytes_estimate(128, 64) > vmem_bytes_estimate(64, 64)
    assert vmem_bytes_estimate(128, 64) < 4 * 1024 * 1024  # DESIGN.md budget
