"""AOT path: lowering produces parseable HLO text with the expected entry
layouts, and the manifest round-trips."""

import os
import subprocess
import sys

from compile.aot import lower_bucket, to_hlo_text


def test_lower_smallest_bucket():
    peel_text, hidx_text = lower_bucket(8, 4)
    assert peel_text.startswith("HloModule")
    assert hidx_text.startswith("HloModule")
    # entry layouts carry the bucket shapes
    assert "s32[8,4]" in peel_text
    assert "s32[8,4]" in hidx_text
    # return_tuple=True: 4-tuple for peel, 2-tuple for hindex
    assert "(s32[8]{0}, s32[8]{0}, s32[], s32[])" in peel_text
    assert "(s32[8]{0}, s32[])" in hidx_text


def test_cli_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--buckets", "8:4"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert (out / "peel_n8_d4.hlo.txt").exists()
    assert (out / "hindex_n8_d4.hlo.txt").exists()
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    assert manifest == ["8 4"]


def test_no_custom_calls_in_lowering():
    # interpret=True must keep the pallas kernels as plain HLO; a Mosaic
    # custom-call would be unloadable by the CPU PJRT client.
    peel_text, hidx_text = lower_bucket(8, 4)
    assert "custom-call" not in peel_text.lower()
    assert "custom-call" not in hidx_text.lower()
