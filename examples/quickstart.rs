//! Quickstart: build a graph, decompose it with both paradigms, inspect
//! the result.
//!
//!     cargo run --release --example quickstart

use pico::core::{index2core::HistoCore, peel::PoDyn, Decomposer};
use pico::graph::{examples, GraphBuilder, GraphStats};

fn main() {
    // 1. The paper's Fig. 1 example graph.
    let g1 = examples::g1();
    println!("G1: {} vertices, {} edges", g1.num_vertices(), g1.num_edges());

    // The optimal Peel algorithm (PeelOne + dynamic frontier).
    let peel = PoDyn.decompose(&g1);
    println!("PO-dyn coreness:    {:?}  (l1 = {})", peel.core, peel.iterations);

    // The optimal Index2core algorithm.
    let histo = HistoCore.decompose(&g1);
    println!("HistoCore coreness: {:?}  (l2 = {})", histo.core, histo.iterations);
    assert_eq!(peel.core, histo.core);

    // 2. Build your own graph.
    let mut b = GraphBuilder::new(0);
    // a 5-clique hanging off a path
    for u in 0..5u32 {
        for v in (u + 1)..5 {
            b.add_edge(u, v);
        }
    }
    b.add_edge(4, 5);
    b.add_edge(5, 6);
    let g = b.build("clique+tail");

    let r = PoDyn.decompose(&g);
    println!(
        "\n{}: coreness = {:?} (k_max = {})",
        g.name,
        r.core,
        r.k_max()
    );
    assert_eq!(r.core, vec![4, 4, 4, 4, 4, 1, 1]);

    // 3. Dataset statistics (the Table II columns).
    let stats = GraphStats::measure(&g).with_kmax(&r.core);
    println!(
        "stats: |V|={} |E|={} d_avg={:.2} d_max={} k_max={:?}",
        stats.vertices, stats.edges, stats.d_avg, stats.d_max, stats.k_max
    );
    println!("\nquickstart OK");
}
