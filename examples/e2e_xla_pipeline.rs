//! End-to-end three-layer driver — the full-stack validation run.
//!
//! Exercises every layer on a real small workload:
//!   L1  Pallas threshold-matrix h-index kernel + assertion-clamp kernel
//!   L2  jax vectorised step functions (peel_step / hindex_step)
//!   AOT HLO-text artifacts (`make artifacts`)
//!   L3  rust: PJRT load + compile, the XlaWorker service thread, the
//!       coordinator scheduler, and the BZ oracle check
//!
//! Workload: the XLA-tier suite (graphs fitting the (4096, 64) bucket).
//! Reports per-graph latency, step counts, and throughput for both
//! vectorised paradigms, cross-validated against the native engine and
//! the serial oracle. The run is recorded in EXPERIMENTS.md §E2E.
//!
//!     make artifacts && cargo run --release --example e2e_xla_pipeline

#[cfg(not(feature = "xla"))]
fn main() -> anyhow::Result<()> {
    eprintln!("SKIP: built without the `xla` feature (cargo run --release --example e2e_xla_pipeline --features xla)");
    Ok(())
}

#[cfg(feature = "xla")]
use pico::bench::suite::{suite, Tier};
#[cfg(feature = "xla")]
use pico::core::bz::bz_coreness;
#[cfg(feature = "xla")]
use pico::core::peel::PoDyn;
#[cfg(feature = "xla")]
use pico::core::Decomposer;
#[cfg(feature = "xla")]
use pico::runtime::{default_worker, VecHindex, VecPeel};
#[cfg(feature = "xla")]
use pico::util::fmt;
#[cfg(feature = "xla")]
use std::time::Instant;

#[cfg(feature = "xla")]
fn main() -> anyhow::Result<()> {
    let worker = default_worker()?;
    println!("pjrt platform: {}", worker.platform()?);
    println!("buckets: {:?}\n", worker.buckets());

    let vec_peel = VecPeel::new(worker.clone());
    let vec_hindex = VecHindex::new(worker.clone());

    println!(
        "{:<12} {:>6} {:>7} {:>5} | {:>10} {:>6} {:>9} | {:>10} {:>5} {:>9} | {:>9}",
        "dataset", "|V|", "|E|", "kmax",
        "vpeel(ms)", "steps", "thru",
        "vhidx(ms)", "l2", "thru",
        "native ms"
    );

    let mut all_ok = true;
    for entry in suite(Tier::Xla) {
        let g = entry.build();
        let oracle = bz_coreness(&g);

        // --- vectorised PeelOne through the whole stack ---
        let t = Instant::now();
        let vp = vec_peel.try_decompose(&g)?;
        let vp_ms = t.elapsed().as_secs_f64() * 1e3;
        let vp_ok = vp.core == oracle;

        // --- vectorised h-index through the whole stack ---
        let t = Instant::now();
        let vh = vec_hindex.try_decompose(&g)?;
        let vh_ms = t.elapsed().as_secs_f64() * 1e3;
        let vh_ok = vh.core == oracle;

        // --- native engine for scale ---
        let t = Instant::now();
        let nat = PoDyn.decompose(&g);
        let nat_ms = t.elapsed().as_secs_f64() * 1e3;
        let nat_ok = nat.core == oracle;

        all_ok &= vp_ok && vh_ok && nat_ok;
        println!(
            "{:<12} {:>6} {:>7} {:>5} | {:>10} {:>6} {:>9} | {:>10} {:>5} {:>9} | {:>9}  {}",
            entry.name,
            g.num_vertices(),
            fmt::si(g.num_edges()),
            vp.k_max(),
            fmt::ms(vp_ms),
            vp.launches,
            fmt::meps(g.num_edges() * vp.launches as u64, vp_ms),
            fmt::ms(vh_ms),
            vh.iterations,
            fmt::meps(g.num_edges() * vh.iterations as u64, vh_ms),
            fmt::ms(nat_ms),
            if vp_ok && vh_ok { "validated" } else { "MISMATCH" },
        );
    }

    // Also prove the oversize path reports a structured error.
    let big = pico::graph::gen::star_burst(1, 200, 0, 3);
    match vec_peel.try_decompose(&big) {
        Err(e) => println!("\noversize graph correctly rejected: {e}"),
        Ok(_) => anyhow::bail!("oversize graph should not fit a bucket"),
    }

    anyhow::ensure!(all_ok, "some validation failed");
    println!("\ne2e_xla_pipeline OK — all layers compose, all outputs oracle-validated");
    Ok(())
}
