//! Social-network analysis — the paper's §I motivation: coreness as an
//! engagement / influence measure and dense-community locator.
//!
//! Generates a power-law "social network" (Barabási–Albert), decomposes it
//! with all four Peel-paradigm algorithms, verifies they agree, and uses
//! the coreness to (a) find the most engaged user cohort (the max-core),
//! (b) report the engagement distribution, (c) contrast atomic-operation
//! budgets — the Fig. 4 story on a realistic workload shape.
//!
//!     cargo run --release --example social_network

use pico::core::{peel, Decomposer};
use pico::graph::gen;
use pico::util::fmt;

fn main() {
    let n = 30_000;
    let g = gen::barabasi_albert(n, 8, 2024);
    println!(
        "social network: {} users, {} friendships, d_max={}",
        fmt::commas(g.num_vertices() as u64),
        fmt::commas(g.num_edges()),
        g.max_degree()
    );

    // All four Peel algorithms, instrumented.
    let algos: Vec<Box<dyn Decomposer>> = vec![
        Box::new(peel::Gpp),
        Box::new(peel::PeelOne),
        Box::new(peel::PpDyn),
        Box::new(peel::PoDyn),
    ];
    let mut reference: Option<Vec<u32>> = None;
    println!(
        "\n{:<10} {:>9} {:>7} {:>14} {:>14}",
        "algorithm", "time(ms)", "l1", "atomic ops", "edge accesses"
    );
    for algo in &algos {
        let t = std::time::Instant::now();
        let r = algo.decompose_with(&g, pico::util::default_threads(), true);
        let ms = t.elapsed().as_secs_f64() * 1e3;
        match &reference {
            None => reference = Some(r.core.clone()),
            Some(expect) => assert_eq!(&r.core, expect, "{} disagrees", algo.name()),
        }
        println!(
            "{:<10} {:>9} {:>7} {:>14} {:>14}",
            algo.name(),
            fmt::ms(ms),
            r.iterations,
            fmt::commas(r.metrics.total_atomics()),
            fmt::commas(r.metrics.edge_accesses),
        );
    }
    let core = reference.unwrap();

    // Engagement analysis.
    let k_max = *core.iter().max().unwrap();
    let max_core: Vec<usize> = (0..core.len()).filter(|&v| core[v] == k_max).collect();
    println!(
        "\nmost engaged cohort: the {}-core has {} users",
        k_max,
        max_core.len()
    );

    // Engagement distribution (how deep do users sit in the hierarchy?).
    let mut hist = vec![0usize; k_max as usize + 1];
    for &c in &core {
        hist[c as usize] += 1;
    }
    println!("coreness distribution (k: users):");
    for (k, cnt) in hist.iter().enumerate() {
        if *cnt > 0 && (k % 2 == 0 || k as u32 == k_max) {
            println!("  {:>3}: {:>8} {}", k, cnt, "#".repeat((cnt * 60 / n).max(1)));
        }
    }

    // Unraveling-prevention insight (paper refs [7]-[10]): users at
    // coreness exactly k_max-1 are the ones an anchored-coreness campaign
    // would target.
    let at_risk = core.iter().filter(|&&c| c == k_max - 1).count();
    println!("\nusers one level below the top core (anchor candidates): {at_risk}");
    println!("\nsocial_network OK");
}
