//! Deep-hierarchy web graphs — the Table VII crossover story.
//!
//! The paper's key finding: on graphs with deep core hierarchies (large
//! k_max relative to size — its indochina-2004, hollywood-2009), the
//! Index2core champion HistoCore beats the Peel champion PO-dyn, because
//! the Peel paradigm's iteration count is *fixed* at l1 = k_max while
//! h-index convergence needs only l2 ≪ k_max sweeps. This example builds
//! shallow and deep graphs of comparable edge count and shows the
//! crossover live.
//!
//!     cargo run --release --example web_hierarchy

use pico::core::{index2core::HistoCore, peel::PoDyn, Decomposer};
use pico::graph::gen;
use pico::util::fmt;

fn run_pair(name: &str, g: &pico::graph::CsrGraph) -> (f64, f64) {
    let threads = pico::util::default_threads();
    let t = std::time::Instant::now();
    let p = PoDyn.decompose_with(g, threads, false);
    let peel_ms = t.elapsed().as_secs_f64() * 1e3;
    let t = std::time::Instant::now();
    let h = HistoCore.decompose_with(g, threads, false);
    let histo_ms = t.elapsed().as_secs_f64() * 1e3;
    assert_eq!(p.core, h.core, "paradigms disagree on {name}");
    println!(
        "{:<14} |E|={:>7}  k_max={:>4}  l1={:>4}  l2={:>3}  PO-dyn={:>8}ms  HistoCore={:>8}ms  -> {}",
        name,
        fmt::si(g.num_edges()),
        p.k_max(),
        p.iterations,
        h.iterations,
        fmt::ms(peel_ms),
        fmt::ms(histo_ms),
        if histo_ms < peel_ms { "HistoCore" } else { "PO-dyn" },
    );
    (peel_ms, histo_ms)
}

fn main() {
    println!("shallow hierarchy (small k_max, Peel's home turf):");
    let shallow = gen::erdos_renyi(40_000, 320_000, 7);
    run_pair("er-shallow", &shallow);
    let grid = gen::grid2d(260, 260);
    run_pair("road-grid", &grid);

    println!("\ndeep hierarchy (k_max large, l2 << l1 = k_max):");
    // clique chain: k_max grows with the biggest clique, h-index
    // converges in a handful of sweeps
    let (deep, _) = gen::nested_cliques(30, 12, 6);
    let (p1, h1) = run_pair("web-cliques", &deep);
    let planted = gen::planted_core(
        30_000,
        150_000,
        &[(6_000, 24), (1_500, 60), (300, 120), (60, 200)],
        23,
    );
    run_pair("web-planted", &planted);

    println!(
        "\nTable VII shape: on the deep-hierarchy graph PO-dyn/HistoCore time ratio = {:.2}x",
        p1 / h1
    );
    println!("web_hierarchy OK");
}
