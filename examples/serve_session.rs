//! End-to-end serving session — the Layer 3.5/3.6/3.7 walkthrough:
//! start `pico serve` in-process, stream edits over TCP, query coreness
//! concurrently while batches land, exercise the sharded backend, ship a
//! binary snapshot to a replica, then serve the same graph as a
//! *cluster* with a remote shard and a read replica.
//!
//! # Who owns what
//!
//! The protocol's *transport* — line/frame codec and every wire magic
//! (`rust/src/net/codec.rs`), the per-connection session state machine
//! with `AUTH` gating and `METRICS` (`net/conn.rs`), the bounded
//! worker-pool server (`net/pool.rs`) with its readiness event thread
//! (`net/poller.rs`), and the one shared client (`net/client.rs`) —
//! lives in the `net` module. Verb *semantics* live
//! in `service::server`, which also carries the authoritative protocol
//! table (CI greps the dispatch tables in `net/conn.rs` against it, so
//! the table cannot drift).
//!
//! Transport knobs on `pico serve`:
//!
//! * `--workers N` — pool threads multiplexing all connections
//!   (default `min(cores, 16)`): connections are queue entries, not
//!   threads. A worker only ever touches a connection whose socket
//!   the readiness poller (`net/poller.rs`) reported readable,
//!   writable, or past a deadline — an *idle* connection costs one
//!   slot in a single `poll(2)` set and zero worker time, so holding
//!   tens of thousands of mostly-idle clients leaves the hot path's
//!   qps flat (the `serve_throughput` bench's idle-fleet section
//!   measures exactly this).
//! * `--max-conns N` — hard connection cap (default 1024); accept
//!   #cap+1 is answered `ERR server at connection capacity (...)` and
//!   closed. The reject line is written best-effort with a short
//!   bounded deadline, so a rejected client that never reads cannot
//!   block the accept thread.
//! * Replies are staged in a bounded per-connection outbound buffer
//!   and flushed by non-blocking writes as the socket turns writable.
//!   Past the buffer's high-water mark the server stops *reading*
//!   that connection (pipelined requests queue in the kernel, not in
//!   server memory), and a peer that stops draining its replies for a
//!   full stall window is cut off and counted in `write_stalled` — a
//!   non-reading client can never pin a worker or wedge a drain.
//! * `PICO_AUTH_TOKEN` env (or `auth_token` in the cluster topology) —
//!   gates the state-mutating shard verbs (`SHARDHOST`, `SHARDAPPLY`,
//!   `SHARDREFINE`, `SHARDSNAP`, `SHARDDELTA`) behind an
//!   `AUTH <token>` preamble, compared in constant time. `pico query`
//!   and the cluster router send it automatically when configured.
//! * `METRICS` (any session) — transport counters:
//!   `OK workers= conn_cap= accepted= active= queued= rejected=
//!   timed_out= write_stalled= reclaimed=` (`rejected` = refused over
//!   the cap, `timed_out` = slow-loris requests cut off mid-read,
//!   `write_stalled` = peers cut off for not draining their replies,
//!   `reclaimed` = idle connections closed to free slots while the
//!   pool sat at its cap).
//! * `METRICS PROM` / `METRICS JSON`, `TRACES [n]` — the [`pico::obs`]
//!   registry: per-graph serve counters, query-latency and per-stage
//!   flush histograms, and the recent-flush trace ring (span trees with
//!   `remote=` attribution for cross-host stages). Section 9 below
//!   walks through them; `pico cluster status --metrics` scrapes and
//!   merges the PROM exposition across every host in a topology.
//!   `pico serve --trace-ring N` sizes the trace ring, and the
//!   `PICO_SLOW_QUERY_US` env sets the slow-query threshold feeding
//!   `pico_slow_queries_total`.
//! * `STATS <window_s> [JSON]`, `EVENTS [n [severity]]`,
//!   `HEALTH [graph]` — the live-ops verbs (section 10): windowed
//!   rates and quantiles from the in-process time-series ring (`pico
//!   serve --sample-interval MS` controls the sampling period, default
//!   1s, ~15 min retention), the severity-tagged structured event
//!   journal (replica failovers, delta-sync fallbacks, write-stall and
//!   slow-loris cutoffs, auth rejects, drains), and the SLO verdict
//!   `ok|degraded|critical` with its reasons. `pico top` polls all
//!   three across every host of a topology into a live dashboard;
//!   `pico cluster status --events|--health` merges them one-shot,
//!   with `--health` exiting non-zero below ok.
//! * `CLUSTER TOPOLOGY|REBALANCE PLAN|REBALANCE APPLY|REBALANCE
//!   MIGRATE|MOVES` — the admin control-plane namespace (section 11):
//!   live shard split/merge and primary migration, driven over the
//!   wire or via `pico cluster rebalance`. Legacy spellings (`SHARDS`)
//!   are thin aliases with byte-identical replies.
//!
//! The same flow over two shells:
//!
//! ```text
//! $ pico serve --dataset social-ba --addr 127.0.0.1:7571 --shards 4 --workers 8
//! $ pico query --cmd 'CORENESS 0; INSERT 17 99; FLUSH; CORENESS 17; SHARDS; METRICS'
//! $ pico query --binary --cmd 'SNAPSHOT 0' --snapshot-file /tmp/shard0.snap
//! $ pico query --binary --cmd 'RESTORE replica' --snapshot-file /tmp/shard0.snap
//! ```
//!
//! And the two-host cluster flow (host B is any machine that can reach
//! host A; loopback works for a dry run):
//!
//! ```text
//! hostB$ pico serve --addr 0.0.0.0:7591          # empty shard host
//! hostA$ cat cluster.toml
//!        [cluster]
//!        name = social
//!        dataset = social-ba
//!        shards = 2
//!        [shard.0]
//!        primary = local
//!        replicas = hostB:7591
//!        [shard.1]
//!        primary = hostB:7591
//! hostA$ pico serve --cluster cluster.toml       # ships shards, serves merged answers
//! hostA$ pico cluster status --cluster cluster.toml
//! hostA$ pico query --cmd 'CORENESS 3; INSERT 17 99; FLUSH; SHARDS'
//! ```
//!
//! `FLUSH` on the cluster routes edits to owner shards, runs the
//! boundary-exchange merge across hosts, journals the epoch's per-shard
//! deltas, and returns — it never blocks on replicas. Replica
//! convergence is the background sync daemon's job (`pico serve
//! --sync-interval`, prints `replica-sync ... synced=` lines): a
//! lagging replica is caught up with a `SHARDDELTA` chain (the journal's
//! routed batches + refined-coreness diffs — bytes scale with the edits,
//! not the graph), falling back to a full `SHARDHOST` manifest re-ship
//! on any gap or corruption. `CORENESS` reads fan out over the shard's
//! replica group with epoch-checked failover, and a shard-local probe
//! (`SHARDCORE <v>`) for a remotely-owned vertex answers
//! `REDIRECT shard= addr= graph=` — `pico query` follows it one hop to
//! the shard host. ctrl-c / SIGTERM on either host drains connections
//! (in-flight requests finish; the bounded pool closes idle ones at
//! their next poll), runs one final sync, and flushes pending edits
//! before exit. `pico cluster status` shows each replica's lag in
//! epochs and the state bytes a full re-ship would cost.
//!
//!     cargo run --release --example serve_session

use pico::cluster::{ClusterConfig, ClusterIndex};
use pico::graph::gen;
use pico::service::server::{read_frame, write_frame, MAX_FRAME_BYTES};
use pico::service::{serve, BatchConfig, CoreService};
use pico::shard::PartitionStrategy;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn send(w: &mut TcpStream, r: &mut BufReader<TcpStream>, cmd: &str) -> String {
    writeln!(w, "{cmd}").unwrap();
    w.flush().unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let reply = line.trim_end().to_string();
    println!("  > {cmd:<18} < {reply}");
    reply
}

/// A verb whose reply is `OK ... lines=<n>` followed by `n` body lines
/// (`METRICS PROM|JSON`, `TRACES`).
fn send_lines(w: &mut TcpStream, r: &mut BufReader<TcpStream>, cmd: &str) -> Vec<String> {
    let head = send(w, r, cmd);
    let n: usize = head
        .split_whitespace()
        .find_map(|t| t.strip_prefix("lines="))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let mut body = Vec::with_capacity(n);
    for _ in 0..n {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        body.push(line.trim_end().to_string());
    }
    body
}

/// One length-prefixed frame out, one back (the server's own framing
/// helpers double as the client side).
fn send_frame(w: &mut TcpStream, r: &mut BufReader<TcpStream>, body: &[u8]) -> Vec<u8> {
    write_frame(w, body).unwrap();
    read_frame(r, MAX_FRAME_BYTES).unwrap().expect("reply frame")
}

fn main() -> anyhow::Result<()> {
    // 1. Host a social-network graph (port 0: pick any free port).
    let g = gen::barabasi_albert(10_000, 6, 2026);
    let service = Arc::new(CoreService::new(BatchConfig::default()));
    service.open("social", &g);
    let handle = serve(service.clone(), "127.0.0.1:0")?;
    println!("serving 'social' on {}\n", handle.addr());

    // 2. A writer connection streams edits; they become visible at FLUSH.
    let ws = TcpStream::connect(handle.addr())?;
    let mut writer = ws.try_clone()?;
    let mut wreader = BufReader::new(ws);
    println!("writer session:");
    send(&mut writer, &mut wreader, "EPOCH");
    send(&mut writer, &mut wreader, "INSERT 3 4071");
    send(&mut writer, &mut wreader, "INSERT 3 9006");
    send(&mut writer, &mut wreader, "DELETE 3 4071"); // coalesces away
    send(&mut writer, &mut wreader, "FLUSH");

    // 3. Readers on their own connections see only published epochs —
    //    here, querying concurrently with another in-flight batch.
    println!("  (queueing 200 more edits silently...)");
    for i in 0..200u32 {
        writeln!(writer, "INSERT {} {}", i % 97, 100 + i)?;
        writer.flush()?;
        let mut line = String::new();
        wreader.read_line(&mut line)?;
        assert!(line.starts_with("OK"), "{line}");
    }
    let reader_thread = std::thread::spawn({
        let addr = handle.addr();
        move || {
            let rs = TcpStream::connect(addr).unwrap();
            let mut w = rs.try_clone().unwrap();
            let mut r = BufReader::new(rs);
            println!("\nconcurrent reader session:");
            send(&mut w, &mut r, "CORENESS 3");
            send(&mut w, &mut r, "DEGENERACY");
            send(&mut w, &mut r, "MEMBERS 8");
            send(&mut w, &mut r, "HISTO");
            send(&mut w, &mut r, "DENSEST");
            send(&mut w, &mut r, "STATS");
            send(&mut w, &mut r, "METRICS"); // transport counters (net::pool)
            send(&mut w, &mut r, "QUIT");
        }
    });
    reader_thread.join().unwrap();

    println!("\nwriter flushes the second batch:");
    send(&mut writer, &mut wreader, "FLUSH");
    send(&mut writer, &mut wreader, "EPOCH");
    send(&mut writer, &mut wreader, "QUIT");

    // 4. The sharded backend: same graph partitioned across 4 shards —
    //    identical answers, merged from per-shard indices at each flush.
    service.open_sharded("social-sharded", &g, 4, PartitionStrategy::Hash);
    let ss = TcpStream::connect(handle.addr())?;
    let mut sw = ss.try_clone()?;
    let mut sreader = BufReader::new(ss);
    println!("\nsharded session (same graph, 4 shards):");
    send(&mut sw, &mut sreader, "USE social-sharded");
    send(&mut sw, &mut sreader, "SHARDS");
    send(&mut sw, &mut sreader, "CORENESS 3");
    send(&mut sw, &mut sreader, "INSERT 3 9006");
    send(&mut sw, &mut sreader, "FLUSH"); // routes + boundary-refines + merges

    // 5. Snapshot shipping over the binary protocol: upgrade with BINARY,
    //    pull shard 0's index as one frame, and hydrate it as a *shard*
    //    replica (the shard's local subgraph + coreness under local ids)
    //    — no recomputation on the restore path. Shipping an unsharded
    //    graph's SNAPSHOT the same way yields a full replica with
    //    identical global answers.
    send(&mut sw, &mut sreader, "BINARY");
    let frame = send_frame(&mut sw, &mut sreader, b"SNAPSHOT 0");
    let nl = frame.iter().position(|&b| b == b'\n').unwrap();
    println!("  > SNAPSHOT 0         < {}", String::from_utf8_lossy(&frame[..nl]));
    let snapshot_bytes = &frame[nl + 1..];
    let mut restore = b"RESTORE social-replica\n".to_vec();
    restore.extend_from_slice(snapshot_bytes);
    let reply = send_frame(&mut sw, &mut sreader, &restore);
    println!(
        "  > RESTORE ({}B)   < {}",
        restore.len(),
        String::from_utf8_lossy(&reply)
    );
    let reply = send_frame(&mut sw, &mut sreader, b"GRAPHS");
    println!("  > GRAPHS             < {}", String::from_utf8_lossy(&reply));
    let _ = send_frame(&mut sw, &mut sreader, b"QUIT");

    // 6. Cluster serving (Layer 3.7): the same graph split across a
    //    local shard and a *remote* shard — hosted by the very server we
    //    started above, dialled over loopback TCP exactly as a second
    //    host would be — plus a read replica for shard 0. The router
    //    ships shard manifests (no remote recomputation), merges with
    //    the boundary exchange across the wire, and answers stay
    //    byte-identical to a single index.
    let addr = handle.addr().to_string();
    let topo = ClusterConfig::parse(&format!(
        "[cluster]\nname = social-cluster\nshards = 2\n\
         [shard.0]\nprimary = local\nreplicas = {addr}\n\
         [shard.1]\nprimary = {addr}\n"
    ))?;
    let cluster = ClusterIndex::build(&g, &topo, pico::service::BatchConfig::default())?;
    println!("\ncluster session ({:?}):", cluster);
    println!("  coreness(3) via the replica group = {:?}", cluster.coreness_routed(3)?);
    cluster.submit(pico::core::EdgeEdit::Insert(3, 9_006));
    let out = cluster.flush()?;
    println!(
        "  flush: epoch {} in {:.2}ms ({} exchange rounds, merge {:.2}ms)",
        out.snapshot.epoch,
        out.elapsed_ms(),
        out.merge.rounds,
        out.merge_ms()
    );
    let report = cluster.sync_replicas()?;
    println!(
        "  catch-up: {} delta(s) + {} snapshot(s) shipped ({} + {} bytes)",
        report.deltas, report.snapshots, report.delta_bytes, report.snapshot_bytes
    );

    // 7. Delta catch-up: let the replica lag three epochs, then watch the
    //    journal serve a SHARDDELTA chain that is a fraction of the full
    //    manifest — catch-up bytes scale with the edit batches, not the
    //    graph.
    let cluster = Arc::new(cluster);
    let base = cluster.epoch();
    for i in 0..3u32 {
        cluster.submit(pico::core::EdgeEdit::Insert(10 + i, 9_500 + i));
        cluster.flush()?; // publishes + journals; replicas untouched
    }
    let chain = cluster
        .journal_chain_bytes(0, base, cluster.epoch())
        .expect("journal covers the lag");
    let full = cluster.groups()[0].primary_manifest(2)?.len();
    println!(
        "\ndelta catch-up (replica {} epochs behind):\n  \
         SHARDDELTA chain = {chain} bytes vs full manifest = {full} bytes ({:.0}x smaller)",
        cluster.epoch() - base,
        full as f64 / chain as f64
    );
    let report = cluster.sync_replicas()?;
    println!(
        "  synced {} replica(s) via deltas ({} bytes); snapshots needed: {}",
        report.deltas, report.delta_bytes, report.snapshots
    );

    // 8. In `pico serve --cluster` the same convergence runs off the
    //    flush path: a jittered background daemon (--sync-interval)
    //    probes replica epochs and prints `replica-sync ... synced=`
    //    lines whenever it ships something. Same machinery, driven here
    //    directly:
    let daemon = pico::service::ReplicaSyncDaemon::spawn(
        cluster.clone(),
        std::time::Duration::from_millis(50),
    );
    cluster.submit(pico::core::EdgeEdit::Insert(0, 9_700));
    cluster.flush()?; // returns immediately; the daemon converges replicas
    for _ in 0..100 {
        let caught_up = cluster.status()[0].replicas[0]
            .1
            .as_ref()
            .map(|st| st.cluster_epoch == cluster.epoch())
            .unwrap_or(false);
        if caught_up {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    println!(
        "\nbackground daemon: {} sync pass(es); group 0 stats: {:?}",
        daemon.syncs(),
        cluster.groups()[0].sync_stats()
    );
    drop(daemon);
    for gs in cluster.status() {
        println!(
            "  shard {}: {} primary @ {} | {} replica(s), {} failovers, {} stale reads, lag {}",
            gs.shard,
            gs.kind,
            gs.primary_addr,
            gs.replicas.len(),
            gs.failovers,
            gs.stale_reads,
            gs.sync.lag_epochs
        );
    }

    // 9. Observability: everything above also landed in the
    //    process-global `obs` registry — per-graph serve counters and
    //    query-latency histograms, per-stage flush timings (queue,
    //    route, apply, refine, commit, publish), replica-sync traffic,
    //    and a bounded ring of flush traces. `METRICS PROM` is the
    //    scrapeable Prometheus exposition, `METRICS JSON` the same
    //    snapshot for tooling, and `TRACES n` replays the most recent
    //    span trees: the cluster flushes above left *stitched* traces
    //    whose remote spans carry the shard host's address and
    //    server-side apply time, so coordinator-vs-network cost is
    //    readable per stage.
    let os = TcpStream::connect(handle.addr())?;
    let mut ow = os.try_clone()?;
    let mut oreader = BufReader::new(os);
    println!("\nobservability session:");
    let prom = send_lines(&mut ow, &mut oreader, "METRICS PROM");
    for line in prom.iter().filter(|l| {
        l.starts_with("pico_flush_total_seconds_count")
            || l.starts_with("pico_serve_queries_total")
            || l.starts_with("pico_sync_deltas_total")
    }) {
        println!("      {line}");
    }
    println!("      ... ({} exposition lines in all)", prom.len());
    for line in send_lines(&mut ow, &mut oreader, "TRACES 1") {
        println!("      {line}");
    }

    // 10. Live monitoring on the same session. Windowed STATS reads the
    //     time-series ring a `pico serve --sample-interval` sampler
    //     fills; with no sampler in this process every key answers n/a
    //     over 0 samples, but the wire shape is the same. EVENTS replays
    //     the journal the cluster work above filled (sync fallbacks,
    //     crossover recomputes), and HEALTH folds the SLO rules into one
    //     verdict. `pico top` polls exactly these three verbs per host;
    //     `pico cluster status --health` turns the worst verdict into
    //     its exit code.
    for line in send_lines(&mut ow, &mut oreader, "STATS 60") {
        println!("      {line}");
    }
    for line in send_lines(&mut ow, &mut oreader, "EVENTS 10") {
        println!("      {line}");
    }
    for line in send_lines(&mut ow, &mut oreader, "HEALTH") {
        println!("      {line}");
    }
    send(&mut ow, &mut oreader, "QUIT");

    // 11. Elastic resharding — the CLUSTER control-plane namespace. A
    //     hot shard sheds its boundary-heaviest vertices to a cooler
    //     shard under the flush fence (export → adopt → release →
    //     router remap → warm re-publish), and a primary can be
    //     live-migrated to another host while writes keep flowing
    //     (`CLUSTER REBALANCE MIGRATE <shard> <host:port>`: manifest +
    //     delta-chain catch-up, then an epoch-verified fenced cutover).
    //     Over the CLI the same surface is `pico cluster rebalance
    //     --addr ...` (dry-run plan), `--apply` (latched execute), and
    //     `--migrate S=ADDR`. The legacy `SHARDS` verb is a thin alias
    //     of `CLUSTER TOPOLOGY` — byte-identical replies, lint-checked.
    service.open_cluster("social-cluster", cluster.clone());
    let cs = TcpStream::connect(handle.addr())?;
    let mut cw = cs.try_clone()?;
    let mut creader = BufReader::new(cs);
    println!("\nrebalance session (CLUSTER namespace):");
    send(&mut cw, &mut creader, "USE social-cluster");
    send(&mut cw, &mut creader, "CLUSTER TOPOLOGY"); // == SHARDS, byte-identical
    for line in send_lines(&mut cw, &mut creader, "CLUSTER REBALANCE PLAN") {
        println!("      {line}");
    }
    // a hot split, driven directly: shard 0 hands 40 vertices to shard
    // 1; journals reset across the move, so the replica takes one full
    // re-ship on the next sync pass and delta catch-up resumes after
    let rec = cluster.move_vertices(0, 1, 40)?;
    println!(
        "  split: {} vertices -> {} ({} bytes shipped, {}us fenced, epoch {} published)",
        rec.vertices, rec.to, rec.bytes, rec.cutover_us, rec.epoch
    );
    cluster.sync_replicas()?;
    println!(
        "  coreness(3) after the split = {:?} (answers never wavered)",
        cluster.coreness_routed(3)?
    );
    for line in send_lines(&mut cw, &mut creader, "CLUSTER MOVES") {
        println!("      {line}");
    }
    send(&mut cw, &mut creader, "QUIT");

    handle.stop();
    println!("\ndone — see rust/src/service/server.rs for the full protocol");
    Ok(())
}
