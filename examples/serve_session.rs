//! End-to-end serving session — the Layer 3.5 walkthrough:
//! start `pico serve` in-process, stream edits over TCP, and query
//! coreness concurrently while batches land.
//!
//! The same flow over two shells:
//!
//! ```text
//! $ pico serve --dataset social-ba --addr 127.0.0.1:7571
//! $ pico query --cmd 'CORENESS 0; INSERT 17 99; FLUSH; CORENESS 17; DENSEST'
//! ```
//!
//!     cargo run --release --example serve_session

use pico::graph::gen;
use pico::service::{serve, BatchConfig, CoreService};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn send(w: &mut TcpStream, r: &mut BufReader<TcpStream>, cmd: &str) -> String {
    writeln!(w, "{cmd}").unwrap();
    w.flush().unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let reply = line.trim_end().to_string();
    println!("  > {cmd:<18} < {reply}");
    reply
}

fn main() -> anyhow::Result<()> {
    // 1. Host a social-network graph (port 0: pick any free port).
    let g = gen::barabasi_albert(10_000, 6, 2026);
    let service = Arc::new(CoreService::new(BatchConfig::default()));
    service.open("social", &g);
    let handle = serve(service, "127.0.0.1:0")?;
    println!("serving 'social' on {}\n", handle.addr());

    // 2. A writer connection streams edits; they become visible at FLUSH.
    let ws = TcpStream::connect(handle.addr())?;
    let mut writer = ws.try_clone()?;
    let mut wreader = BufReader::new(ws);
    println!("writer session:");
    send(&mut writer, &mut wreader, "EPOCH");
    send(&mut writer, &mut wreader, "INSERT 3 4071");
    send(&mut writer, &mut wreader, "INSERT 3 9006");
    send(&mut writer, &mut wreader, "DELETE 3 4071"); // coalesces away
    send(&mut writer, &mut wreader, "FLUSH");

    // 3. Readers on their own connections see only published epochs —
    //    here, querying concurrently with another in-flight batch.
    println!("  (queueing 200 more edits silently...)");
    for i in 0..200u32 {
        writeln!(writer, "INSERT {} {}", i % 97, 100 + i)?;
        writer.flush()?;
        let mut line = String::new();
        wreader.read_line(&mut line)?;
        assert!(line.starts_with("OK"), "{line}");
    }
    let reader_thread = std::thread::spawn({
        let addr = handle.addr();
        move || {
            let rs = TcpStream::connect(addr).unwrap();
            let mut w = rs.try_clone().unwrap();
            let mut r = BufReader::new(rs);
            println!("\nconcurrent reader session:");
            send(&mut w, &mut r, "CORENESS 3");
            send(&mut w, &mut r, "DEGENERACY");
            send(&mut w, &mut r, "MEMBERS 8");
            send(&mut w, &mut r, "HISTO");
            send(&mut w, &mut r, "DENSEST");
            send(&mut w, &mut r, "STATS");
            send(&mut w, &mut r, "QUIT");
        }
    });
    reader_thread.join().unwrap();

    println!("\nwriter flushes the second batch:");
    send(&mut writer, &mut wreader, "FLUSH");
    send(&mut writer, &mut wreader, "EPOCH");
    send(&mut writer, &mut wreader, "QUIT");

    handle.stop();
    println!("\ndone — see rust/src/service/server.rs for the full protocol");
    Ok(())
}
